"""Quickstart: solve a sparse system Ax=b with HYLU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import scipy.sparse as sp

from repro.core import CSR, solve_system

# build a small FEM-ish system
n = 2500
nx = int(np.sqrt(n))
e = np.ones(nx)
t = sp.diags([-e[:-1], 2 * e, -e[:-1]], [-1, 0, 1])
a = sp.kronsum(t, t).tocsr()
a = a + sp.diags(np.random.default_rng(0).uniform(0, 0.1, a.shape[0]))
b = np.random.default_rng(1).normal(size=a.shape[0])

A = CSR.from_scipy(a)
x, info = solve_system(A, b)

print(f"n={A.n} nnz={A.nnz}")
print(f"kernel mode selected : {info['mode']}")
print(f"ordering selected    : {info['ordering']}")
print(f"residual |Ax-b|/|b|  : {info['residual']:.3e}")
print(f"pivot perturbations  : {info['n_perturb']}")
print(f"refinement steps     : {info['n_refine']}")
t = info["timings"]
print(f"preprocess {t['preprocess']['total']*1e3:.1f} ms | "
      f"factor {t['factor']['factor']*1e3:.1f} ms")
assert info["residual"] < 1e-10
print("OK")
