"""Differentiable sparse solve (beyond-paper): learn circuit conductances
from observed node voltages by gradient descent THROUGH the HYLU solver.

The forward pass solves G(θ) v = i with the JAX engine; the backward pass
reuses the same LU factors for the adjoint solve (custom_vjp) — one
factorization + two triangular solves per training step.

    PYTHONPATH=src python examples/learn_conductances.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import CSR, analyze, make_sparse_solve


def main():
    rng = np.random.default_rng(0)
    n = 120
    # random resistor network (Laplacian + ground leaks)
    m = 4 * n
    r = rng.integers(0, n, m)
    c = np.clip(r + rng.integers(1, 6, m), 0, n - 1)
    keep = r != c
    r, c = r[keep], c[keep]
    g_true = rng.uniform(0.5, 2.0, len(r))

    def laplacian_data(g):
        # CSR.from_coo keeps the union pattern regardless of values, so the
        # sparsity pattern is identical for every g (required: one analysis)
        d = np.bincount(r, g, n) + np.bincount(c, g, n) + 0.1
        rows = np.concatenate([r, c, np.arange(n)])
        cols = np.concatenate([c, r, np.arange(n)])
        vals = np.concatenate([-g, -g, d])
        return CSR.from_coo(n, rows, cols, vals)

    A_true = laplacian_data(g_true)
    an = analyze(A_true)                       # pattern fixed → one analysis
    solve = make_sparse_solve(an)

    i_src = rng.normal(size=n)
    v_obs = np.asarray(solve(jnp.asarray(A_true.data), jnp.asarray(i_src)))

    # learn log-conductances
    theta = jnp.zeros(len(r))                  # g = exp(theta), start at 1.0
    pattern_ref = laplacian_data(np.ones(len(r)))

    # differentiable assembly: data = M @ g + d0 (linear in g) — precompute M
    nnz = pattern_ref.nnz
    M = np.zeros((nnz, len(r)))
    base = laplacian_data(np.zeros(len(r))).data
    for k in range(len(r)):
        gk = np.zeros(len(r))
        gk[k] = 1.0
        M[:, k] = laplacian_data(gk).data - base
    M = jnp.asarray(M)
    d0 = jnp.asarray(base)

    @jax.jit
    def loss_fn(theta):
        g = jnp.exp(theta)
        data = M @ g + d0
        v = solve(data, jnp.asarray(i_src))
        return jnp.mean((v - jnp.asarray(v_obs)) ** 2)

    grad_fn = jax.jit(jax.grad(loss_fn))
    # Adam on log-conductances
    m_ = jnp.zeros_like(theta)
    v_ = jnp.zeros_like(theta)
    lr = 0.05
    l0 = float(loss_fn(theta))
    for it in range(150):
        g_ = grad_fn(theta)
        m_ = 0.9 * m_ + 0.1 * g_
        v_ = 0.999 * v_ + 0.001 * g_ * g_
        theta = theta - lr * m_ / (jnp.sqrt(v_ / (1 - 0.999 ** (it + 1)))
                                   + 1e-8) / (1 - 0.9 ** (it + 1)) * \
            (1 - 0.9 ** (it + 1))
        if it % 25 == 0:
            err = float(jnp.abs(jnp.exp(theta) - jnp.asarray(g_true)).mean())
            print(f"iter {it:3d} loss {float(loss_fn(theta)):.3e} "
                  f"mean|g-g*| {err:.3f}")
    final = float(loss_fn(theta))
    print(f"loss: {l0:.3e} → {final:.3e} ({l0/final:.0f}x reduction)")
    assert final < l0 / 50
    print("OK")


if __name__ == "__main__":
    main()
