"""End-to-end training driver: train a ~100M-param phi3-family model for a
few hundred steps on the synthetic pipeline, with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: phi3 family topology, scaled down
    cfg = ArchConfig(name="phi3-100m", family="dense", n_layers=6,
                     d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
                     d_ff=2048, vocab=32000, act="swiglu", rope_type="std")
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params, "
          f"devices={len(jax.devices())}")

    data = SyntheticLM(vocab=cfg.vocab, seq_len=128, global_batch=8, seed=0)
    tr = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20, seq_chunk=128),
        cfg, params, data,
        opt_cfg=adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=args.steps))
    tr.install_signal_handler()
    resumed = tr.maybe_resume()
    if resumed:
        print(f"resumed from step {resumed}")
    log = tr.run()
    print(f"loss: {log[0]['loss']:.3f} → {log[-1]['loss']:.3f} "
          f"over {len(log)} steps; stragglers={tr.n_stragglers}")
    assert log[-1]["loss"] < log[0]["loss"]
    print("OK")


if __name__ == "__main__":
    main()
