"""Mixed-pattern serving demo: heterogeneous solve traffic through
``SolverService`` with a persistent plan cache.

Production traffic is not one pre-analyzed pattern: a serving process sees
circuit matrices next to banded PDE operators next to general unsymmetric
systems, interleaved arbitrarily.  This demo builds exactly such a stream
and pushes it through the serving stack three times:

  cold    first touch of every pattern: fingerprint → plan-cache miss →
          host analyze → artifact persisted → XLA compile → solve
  warm    same patterns, new values: every plan + compiled engine is an
          in-memory cache hit — only the solves remain
  fresh   a NEW SolverService over the same cache directory (simulating a
          restarted process): plans load from checkpoints/ (the analyze
          phase is skipped; the counter proves it) and only XLA compile is
          re-paid — which the persistent jax compilation cache absorbs in
          real deployments

    PYTHONPATH=src python examples/mixed_pattern_serving.py \
        [--requests 24] [--batch-size 8] [--devices 2] \
        [--cache-dir checkpoints/plan_cache_demo]
"""
import argparse
import os
import sys
import time

import numpy as np

# --devices must act before jax's CPU backend initializes
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=1)
_pre_args, _ = _pre.parse_known_args()
if _pre_args.devices > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_pre_args.devices}")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.core import CSR, HyluOptions  # noqa: E402
from repro.serve.solver_service import SolverService, SolveRequest  # noqa: E402


def patterns(scale=1.0):
    """Three structurally distinct workloads (the serving mix)."""
    from matrices import banded, circuit_like, unsym_random
    return [
        ("circuit", CSR.from_scipy(circuit_like(int(200 * scale), 1)
                                   .tocsr())),
        ("banded", CSR.from_scipy(banded(int(150 * scale), 6, 2).tocsr())),
        ("unsym", CSR.from_scipy(unsym_random(int(120 * scale), 0.02, 8)
                                 .tocsr())),
    ]


def make_stream(pats, n_requests, seed):
    """Interleaved, shuffled requests with per-request value drift."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        name, Ac = pats[i % len(pats)]
        reqs.append(SolveRequest(
            a=CSR(Ac.n, Ac.indptr, Ac.indices,
                  Ac.data * rng.uniform(0.9, 1.1, Ac.nnz)),
            b=rng.normal(size=Ac.n), tag=name))
    rng.shuffle(reqs)
    return reqs


def run_window(svc, reqs, label):
    t0 = time.perf_counter()
    res = svc.solve_batch(reqs)
    dt = time.perf_counter() - t0
    worst = max(float(np.max(r.residual)) for r in res)
    cs = svc.cache.stats
    print(f"[{label:5s}] {len(reqs):3d} requests in {dt:7.2f}s "
          f"({len(reqs) / dt:8.1f} req/s)  worst resid {worst:.1e}  "
          f"cache: mem={cs['hits']} disk={cs['disk_hits']} "
          f"analyze={cs['analyze_calls']}")
    assert worst < 1e-8
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(parents=[_pre])
    ap.add_argument("--requests", type=int, default=24,
                    help="requests per serving window")
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--cache-dir", default="checkpoints/plan_cache_demo")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--jax-cache", default=None, metavar="DIR",
                    help="persistent JAX compilation cache dir ('' "
                         "disables) — with it, the 'fresh' window pays "
                         "neither analyze nor compile")
    args = ap.parse_args(argv)

    from _jax_cache import enable_jax_compilation_cache
    jc = enable_jax_compilation_cache(args.jax_cache)
    if jc:
        print(f"[jax] persistent compilation cache at {jc}")

    opts = HyluOptions(mesh=args.devices if args.devices > 1 else None)
    pats = patterns(args.scale)
    print(f"serving mix: "
          + ", ".join(f"{n} (n={A.n}, nnz={A.nnz})" for n, A in pats)
          + (f"  [mesh over {args.devices} devices]"
             if args.devices > 1 else ""))

    svc = SolverService(opts=opts, cache_dir=args.cache_dir,
                        batch_size=args.batch_size)
    run_window(svc, make_stream(pats, args.requests, seed=1), "cold")
    run_window(svc, make_stream(pats, args.requests, seed=2), "warm")

    # a restarted process: new service, same artifact store
    svc2 = SolverService(opts=opts, cache_dir=args.cache_dir,
                         batch_size=args.batch_size)
    run_window(svc2, make_stream(pats, args.requests, seed=3), "fresh")
    assert svc2.cache.stats["analyze_calls"] == 0, \
        "fresh process should load every plan from the artifact store"
    assert svc2.cache.stats["disk_hits"] == len(pats)

    modes = {name: svc.pattern_modes[
        svc.cache.fingerprint(Ac, opts)] for name, Ac in pats}
    print(f"kernel routing: {modes}")
    print(f"artifact store: {args.cache_dir} "
          f"({len(os.listdir(args.cache_dir))} plans)")
    print("MIXED_PATTERN_SERVING_OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
