"""Repeated-solve production scenario (paper §3.2): transient circuit
simulation — one analysis, many refactor+solve steps — on all three
repeated-solve engines, plus a batched Monte-Carlo corner sweep.

A linear RC network driven by a time-varying source, backward-Euler
integration:  (G + C/dt) v_t = C/dt v_{t-1} + i(t).
The conductance matrix values change every Newton/time step (here: dt
modulation) while the sparsity pattern is fixed — exactly HYLU's
repeated-solve optimization.  The three paths:

  ref          numpy reference engine (looped refactor + solve)
  jax          pre-compiled XLA refactor/solve per step (engine="jax";
               one compile, then every step is two XLA calls)
  jax-batched  K Monte-Carlo conductance corners factored + solved as ONE
               vmapped XLA program (solve_sequence) — the corner-analysis
               workload circuit simulators batch in production

plus the multi-device finale: a T-step × K-corner sweep through the async
double-buffered ``solve_sequence`` pipeline, sharded over the system-batch
axis when more than one device is available (``--devices N`` forces N
virtual CPU devices — it must be processed before jax initializes, which
this script does) and with buffer donation keeping the refactor stream
allocation-flat.

    PYTHONPATH=src python examples/circuit_transient.py \
        [--n 240] [--steps 20] [--corners 32] [--devices 2]
"""
import argparse
import time

import numpy as np

import os
import sys

# --devices must act before jax's CPU backend initializes
_pre = argparse.ArgumentParser(add_help=False)
_pre.add_argument("--devices", type=int, default=1)
_pre_args, _ = _pre.parse_known_args()
if _pre_args.devices > 1:
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_pre_args.devices}")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.core import (CSR, HyluOptions, analyze, factor, refactor, solve,
                        solve_sequence)


def rc_network(n, seed=0):
    from matrices import circuit_like
    g = circuit_like(n, seed).tocsr()
    rng = np.random.default_rng(seed)
    c = rng.uniform(1e-12, 1e-9, n)          # node capacitances
    return g, c


def transient(an, A0, c, n_steps, dt, engine):
    """Backward-Euler time stepping on one engine; returns (v, timings)."""
    n = A0.n
    rng = np.random.default_rng(7)
    diag_idx = np.where(A0.indices == np.repeat(
        np.arange(n), np.diff(A0.indptr)))[0]
    v = np.zeros(n)
    st = None
    t_fac = t_sol = 0.0
    for step in range(n_steps):
        dt_k = dt * (1.0 + 0.5 * np.sin(step / 5.0))     # variable step
        data = A0.data.copy()
        data[diag_idx] += c / dt_k
        Ak = CSR(n, A0.indptr, A0.indices, data)
        t0 = time.perf_counter()
        st = refactor(st, Ak) if st is not None else factor(an, Ak,
                                                            engine=engine)
        t_fac += time.perf_counter() - t0
        i_src = np.zeros(n)
        i_src[rng.integers(0, n, 5)] = rng.normal(size=5)
        rhs = c / dt_k * v + i_src
        t0 = time.perf_counter()
        v, info = solve(st, rhs)
        t_sol += time.perf_counter() - t0
        assert info["residual"] < 1e-8, (engine, step, info)
    return v, t_fac, t_sol


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=240)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--corners", type=int, default=32)
    ap.add_argument("--devices", type=int, default=1,
                    help="shard the batched sweeps over N (virtual CPU) "
                         "devices")
    args = ap.parse_args(argv)
    n, n_steps = args.n, args.steps
    dt = 1e-6

    g, c = rc_network(n)
    A0 = CSR.from_scipy(g)

    t0 = time.perf_counter()
    an = analyze(A0)
    t_analyze = time.perf_counter() - t0
    print(f"analysis: {t_analyze*1e3:.0f} ms "
          f"(n={n}, mode={an.choice.mode}, ordering={an.ordering_name})")

    # ---- sequential transient: ref vs jitted-jax --------------------------
    v_ref, fac_ref, sol_ref = transient(an, A0, c, n_steps, dt, "ref")
    print(f"[ref]  {n_steps} steps: refactor {fac_ref*1e3:7.1f} ms, "
          f"solve {sol_ref*1e3:7.1f} ms")

    t0 = time.perf_counter()
    st_warm = factor(an, A0, engine="jax")    # compile refactor, up front
    solve(st_warm, np.zeros(n))               # compile the solve path too
    t_compile = time.perf_counter() - t0
    v_jax, fac_jax, sol_jax = transient(an, A0, c, n_steps, dt, "jax")
    print(f"[jax]  {n_steps} steps: refactor {fac_jax*1e3:7.1f} ms, "
          f"solve {sol_jax*1e3:7.1f} ms "
          f"(+{t_compile:.1f}s one-time compile) — "
          f"{(fac_ref+sol_ref)/(fac_jax+sol_jax):.1f}x vs ref per step")
    assert np.abs(v_ref - v_jax).max() <= 1e-8 * (1 + np.abs(v_ref).max())

    # ---- batched Monte-Carlo corner sweep: one vmapped XLA program --------
    k = args.corners
    rng = np.random.default_rng(42)
    vb = A0.data[None, :] * rng.uniform(0.8, 1.2, (k, A0.nnz))
    i_dc = np.zeros(n)
    i_dc[rng.integers(0, n, 8)] = rng.normal(size=8)
    t0 = time.perf_counter()
    x, info = solve_sequence(A0, vb, i_dc)
    t_batch = time.perf_counter() - t0
    print(f"[jax-batched] {k} conductance corners, one XLA program: "
          f"{t_batch*1e3:.0f} ms total (incl. compile), "
          f"max residual {float(info['residual'].max()):.2e}")
    assert float(info["residual"].max()) < 1e-8

    # per-corner spread of the DC operating point — the payoff of the sweep
    spread = np.abs(x).max(axis=1)
    print(f"corner spread of |v|max: {spread.min():.3e} … {spread.max():.3e}")

    # ---- multi-RHS: per-corner sensitivity to M independent source sets —
    # b of shape (K, n, M) rides the same fused solve+refinement program ----
    m_src = 4
    bm = np.zeros((k, n, m_src))
    for j in range(m_src):
        bm[:, rng.integers(0, n, 6), j] = rng.normal(size=6)
    t0 = time.perf_counter()
    xs, info_m = solve_sequence(A0, vb, bm)
    t_multi = time.perf_counter() - t0
    print(f"[jax-batched] multi-RHS sensitivity sweep x{m_src}: "
          f"x {xs.shape}, residual (K, M) max "
          f"{float(info_m['residual'].max()):.2e}, {t_multi*1e3:.0f} ms")
    assert xs.shape == (k, n, m_src)
    assert float(info_m["residual"].max()) < 1e-8

    # ---- sharded async pipeline: T transient steps × K corners ------------
    # Each step's K corner matrices are factored+solved as one (sharded)
    # XLA program while the host stages the next step's values; donation
    # keeps the refactor stream allocation-flat.  RHS here are per-step
    # source vectors (independent across steps, so nothing serializes the
    # pipeline — the corner-sweep-over-a-transient workload).
    n_dev = min(args.devices, len(jax.devices()))
    t_seq_steps = min(args.steps, 8)
    diag_idx = np.where(A0.indices == np.repeat(
        np.arange(n), np.diff(A0.indptr)))[0]
    steps_v, steps_b = [], []
    for step in range(t_seq_steps):
        dt_k = dt * (1.0 + 0.5 * np.sin(step / 5.0))
        data = A0.data.copy()
        data[diag_idx] += c / dt_k
        steps_v.append(data[None, :] * rng.uniform(0.8, 1.2, (k, A0.nnz)))
        b_t = np.zeros((k, n))
        b_t[:, rng.integers(0, n, 5)] = rng.normal(size=5)
        steps_b.append(b_t)
    opts_seq = HyluOptions(mesh=(n_dev if n_dev > 1 else None), donate=True)
    t0 = time.perf_counter()
    xt, info_t = solve_sequence(A0, steps_v, steps_b, opts_seq)
    t_seq = time.perf_counter() - t0
    print(f"[jax-sharded] {t_seq_steps} steps x {k} corners on "
          f"{n_dev} device(s), double-buffered+donating pipeline: "
          f"x {xt.shape}, max residual {float(info_t['residual'].max()):.2e}, "
          f"{t_seq*1e3:.0f} ms total (incl. analysis+compile)")
    assert xt.shape == (t_seq_steps, k, n)
    assert float(info_t["residual"].max()) < 1e-8
    print("OK")


if __name__ == "__main__":
    main()
