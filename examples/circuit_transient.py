"""Repeated-solve production scenario (paper §3.2): transient circuit
simulation — one analysis, thousands of refactor+solve steps.

A linear RC network driven by a time-varying source, backward-Euler
integration:  (G + C/dt) v_t = C/dt v_{t-1} + i(t).
The conductance matrix values change every Newton/time step (here: dt
modulation) while the sparsity pattern is fixed — exactly HYLU's
repeated-solve optimization.

    PYTHONPATH=src python examples/circuit_transient.py
"""
import time

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "benchmarks"))

from repro.core import CSR, analyze, factor, refactor, solve
from repro.core import baselines as B


def rc_network(n, seed=0):
    from matrices import circuit_like
    g = circuit_like(n, seed).tocsr()
    rng = np.random.default_rng(seed)
    c = rng.uniform(1e-12, 1e-9, n)          # node capacitances
    return g, c


def main():
    n = 3000
    g, c = rc_network(n)
    A0 = CSR.from_scipy(g)
    n_steps = 40
    dt = 1e-6

    t0 = time.perf_counter()
    an = analyze(A0)
    t_analyze = time.perf_counter() - t0
    print(f"analysis: {t_analyze*1e3:.0f} ms "
          f"(mode={an.choice.mode}, ordering={an.ordering_name})")

    rng = np.random.default_rng(7)
    v = np.zeros(n)
    st = None
    t_fac, t_sol = 0.0, 0.0
    diag_idx = np.where(A0.indices == np.repeat(
        np.arange(n), np.diff(A0.indptr)))[0]
    for step in range(n_steps):
        dt_k = dt * (1.0 + 0.5 * np.sin(step / 5.0))     # variable step
        data = A0.data.copy()
        data[diag_idx] += c / dt_k
        Ak = CSR(n, A0.indptr, A0.indices, data)
        t0 = time.perf_counter()
        st = refactor(st, Ak) if st is not None else factor(an, Ak)
        t_fac += time.perf_counter() - t0
        i_src = np.zeros(n)
        i_src[rng.integers(0, n, 5)] = rng.normal(size=5)
        rhs = c / dt_k * v + i_src
        t0 = time.perf_counter()
        v, info = solve(st, rhs)
        t_sol += time.perf_counter() - t0
        assert info["residual"] < 1e-8, (step, info)

    print(f"{n_steps} transient steps: refactor {t_fac*1e3:.0f} ms total "
          f"({t_fac/n_steps*1e3:.1f} ms/step), solve {t_sol*1e3:.0f} ms total")
    print(f"amortized analysis share: "
          f"{t_analyze/(t_analyze+t_fac+t_sol)*100:.1f}% "
          f"(one-time, reused {n_steps}×)")
    print("final |v| =", float(np.abs(v).max()))
    print("OK")


if __name__ == "__main__":
    main()
