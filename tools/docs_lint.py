"""Docs lint (the CI docs step; also run by tests/test_docs.py).

Checks, repo-relative:
  1. every internal markdown link in docs/*.md, README.md and ROADMAP.md
     resolves — the file exists, and when the link carries a #fragment the
     target heading exists (GitHub-style slugs);
  2. every ``HyluOptions`` field is documented in docs/API.md (the options
     table must not rot as knobs are added);
  3. the three core docs exist and are linked from README.md;
  4. the serving stack's public options stay documented in docs/API.md:
     every ``PlanCache``/``SolverService`` constructor parameter, every
     ``SolveRequest``/``SolveResult`` field, and every plan-fingerprint
     option field (``PLAN_OPTION_FIELDS``);
  5. the corpus scale lane stays documented: every corpus matrix and
     ``large``-section record field in docs/BENCHMARKS.md, the memory
     accounting + amalgamation + cache-root surface in docs/API.md;
  6. the mixed-precision surface stays documented: the dtype resolvers
     and per-system failure/fallback info fields in docs/API.md, the
     precision dataflow in docs/ARCHITECTURE.md, and the
     ``mixed_precision`` bench fields + ``--mixed-only`` flag in
     docs/BENCHMARKS.md;
  7. the fault-tolerant async serving surface stays documented: every
     error-taxonomy code and terminal status, the ``AsyncSolverServer``
     parameters and stats in docs/API.md, the async dataflow in
     docs/ARCHITECTURE.md, and the ``serving_async`` bench fields +
     ``--serving-async`` flag + serving-chaos lane in docs/BENCHMARKS.md.

    PYTHONPATH=src python tools/docs_lint.py
"""
from __future__ import annotations

import dataclasses
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOC_FILES = ("README.md", "ROADMAP.md", "docs/ARCHITECTURE.md",
             "docs/API.md", "docs/BENCHMARKS.md")
CORE_DOCS = ("docs/ARCHITECTURE.md", "docs/API.md", "docs/BENCHMARKS.md")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.M)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of a markdown heading."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        return {_slug(m.group(1)) for m in _HEADING.finditer(f.read())}


def check_links() -> list:
    errors = []
    for rel in DOC_FILES:
        path = os.path.join(REPO, rel)
        if not os.path.exists(path):
            errors.append(f"{rel}: file missing")
            continue
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            tpath = (path if not target
                     else os.path.normpath(
                         os.path.join(os.path.dirname(path), target)))
            if not os.path.exists(tpath):
                errors.append(f"{rel}: broken link -> {target}")
                continue
            if frag and tpath.endswith(".md"):
                if _slug(frag) not in _anchors(tpath):
                    errors.append(f"{rel}: broken anchor -> "
                                  f"{target or rel}#{frag}")
    return errors


def check_options_documented() -> list:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.api import HyluOptions

    with open(os.path.join(REPO, "docs/API.md"), encoding="utf-8") as f:
        text = f.read()
    return [f"docs/API.md: HyluOptions field `{f.name}` undocumented"
            for f in dataclasses.fields(HyluOptions)
            if f"`{f.name}`" not in text]


def check_serving_documented() -> list:
    """Plan-cache + serving public surface: constructor params, result
    fields and the fingerprint option list must appear in docs/API.md."""
    import inspect

    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core.options import PLAN_OPTION_FIELDS
    from repro.core.plan_cache import PlanCache
    from repro.serve.solver_service import (SolverService, SolveRequest,
                                            SolveResult)

    with open(os.path.join(REPO, "docs/API.md"), encoding="utf-8") as f:
        text = f.read()
    errors = []
    for cls in (PlanCache, SolverService, SolveRequest, SolveResult):
        if f"`{cls.__name__}`" not in text:
            errors.append(f"docs/API.md: class `{cls.__name__}` "
                          "undocumented")
    named = {
        "PlanCache": [f.name for f in dataclasses.fields(PlanCache)],
        "SolverService": [p for p in inspect.signature(
            SolverService.__init__).parameters if p != "self"],
        "SolveRequest": [f.name for f in dataclasses.fields(SolveRequest)],
        "SolveResult": [f.name for f in dataclasses.fields(SolveResult)],
        "PLAN_OPTION_FIELDS": list(PLAN_OPTION_FIELDS),
    }
    for owner, names in named.items():
        errors.extend(
            f"docs/API.md: {owner} option/field `{n}` undocumented"
            for n in names if f"`{n}`" not in text)
    return errors


def check_scale_lane_documented() -> list:
    """The corpus scale lane's public surface: every corpus entry and
    every bench_corpus_entry record field must appear in
    docs/BENCHMARKS.md, and the memory/amalgamation API must appear in
    docs/API.md (the `large` JSON section must not rot as the scale lane
    grows)."""
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.path.insert(0, REPO)
    from benchmarks import corpus

    with open(os.path.join(REPO, "docs/BENCHMARKS.md"),
              encoding="utf-8") as f:
        bench_text = f.read()
    with open(os.path.join(REPO, "docs/API.md"), encoding="utf-8") as f:
        api_text = f.read()
    errors = []
    for name in [e.name for e in corpus.corpus()]:
        if name not in bench_text:
            errors.append(f"docs/BENCHMARKS.md: corpus matrix `{name}` "
                          "undocumented")
    record_fields = ("load_s", "analyze_s", "schedule_s", "compile_s",
                     "refac_batched_s", "solve_fused_s", "amalg",
                     "memory_bytes", "engine_memory_bytes", "peak_rss_mb",
                     "worst_residual", "pad_waste_frac",
                     "n_scanned_levels", "bulk_node_coverage")
    errors.extend(
        f"docs/BENCHMARKS.md: `large` record field `{n}` undocumented"
        for n in record_fields if f"`{n}`" not in bench_text)
    for flag in ("--large-smoke", "--large-only", "--large-k",
                 "--amalg-tol", "HYLU_CORPUS_OFFLINE"):
        if flag not in bench_text:
            errors.append(f"docs/BENCHMARKS.md: bench flag `{flag}` "
                          "undocumented")
    for name in ("memory_stats", "amalgamate_supernodes",
                 "HYLU_CACHE_ROOT", "resolve_cache_dir"):
        if name not in api_text:
            errors.append(f"docs/API.md: `{name}` undocumented")
    memory_fields = ("panel_bytes", "workspace_bytes",
                     "schedule_index_bytes", "batched_bytes",
                     "total_bytes")
    errors.extend(
        f"docs/API.md: memory_stats field `{n}` undocumented"
        for n in memory_fields if f"`{n}`" not in api_text)
    return errors


def check_mixed_precision_documented() -> list:
    """The mixed-precision surface: dtype resolvers + per-system
    failure/fallback info fields in docs/API.md, the precision dataflow
    in docs/ARCHITECTURE.md, and the ``mixed_precision`` bench section in
    docs/BENCHMARKS.md."""
    with open(os.path.join(REPO, "docs/API.md"), encoding="utf-8") as f:
        api_text = f.read()
    with open(os.path.join(REPO, "docs/ARCHITECTURE.md"),
              encoding="utf-8") as f:
        arch_text = f.read()
    with open(os.path.join(REPO, "docs/BENCHMARKS.md"),
              encoding="utf-8") as f:
        bench_text = f.read()
    errors = []
    # plain substring: these appear inside signatures / info["..."] forms
    for name in ("resolve_perturb_eps", "resolve_refine_tol",
                 "resolve_dtype_names", "dtype_name", "np_dtype",
                 "refine_failed", "refine_stalled", "fallback_mask",
                 "n_fp64_fallback"):
        if name not in api_text:
            errors.append(f"docs/API.md: mixed-precision name `{name}` "
                          "undocumented")
    for name in ("factor_dtype", "refine_dtype", "fp64_fallback"):
        if f"`{name}`" not in arch_text:
            errors.append(f"docs/ARCHITECTURE.md: precision-dataflow "
                          f"name `{name}` unmentioned")
    mixed_fields = ("speedup_refac_fp32", "speedup_solve_fp32",
                    "panel_bytes_ratio", "x_diff_vs_fp64",
                    "worst_residual", "fallback_rate", "n_fp64_fallback",
                    "factor_panel_bytes", "n_refine_per_system_max")
    errors.extend(
        f"docs/BENCHMARKS.md: `mixed_precision` field `{n}` undocumented"
        for n in mixed_fields if n not in bench_text)
    if "--mixed-only" not in bench_text:
        errors.append("docs/BENCHMARKS.md: bench flag `--mixed-only` "
                      "undocumented")
    return errors


def check_async_serving_documented() -> list:
    """The fault-tolerant async serving surface: the error taxonomy codes
    and terminal statuses, every ``AsyncSolverServer`` constructor
    parameter and server-stats field in docs/API.md, the async dataflow
    in docs/ARCHITECTURE.md, and the ``serving_async`` bench fields +
    ``--serving-async`` flag + serving-chaos lane in docs/BENCHMARKS.md."""
    import inspect

    sys.path.insert(0, os.path.join(REPO, "src"))
    import repro.serve.solver_service as ss
    from repro.serve.async_server import AsyncSolverServer

    with open(os.path.join(REPO, "docs/API.md"), encoding="utf-8") as f:
        api_text = f.read()
    with open(os.path.join(REPO, "docs/ARCHITECTURE.md"),
              encoding="utf-8") as f:
        arch_text = f.read()
    with open(os.path.join(REPO, "docs/BENCHMARKS.md"),
              encoding="utf-8") as f:
        bench_text = f.read()
    errors = []
    # every taxonomy code and terminal status, introspected from the
    # module constants so new codes cannot ship undocumented
    codes = [getattr(ss, n) for n in dir(ss) if n.startswith("ERR_")]
    for code in codes + list(ss.TERMINAL_STATUSES):
        if f"`{code}`" not in api_text:
            errors.append(f"docs/API.md: error code / status `{code}` "
                          "undocumented")
    for name in ("SolveError", "InvalidRequestError", "validate_request",
                 "TERMINAL_STATUSES", "resolve_retry_perturb",
                 "AsyncSolverServer", "escalation"):
        if name not in api_text:
            errors.append(f"docs/API.md: async-serving name `{name}` "
                          "undocumented")
    params = [p for p in inspect.signature(
        AsyncSolverServer.__init__).parameters if p != "self"]
    errors.extend(
        f"docs/API.md: AsyncSolverServer parameter `{p}` undocumented"
        for p in params if f"`{p}`" not in api_text)
    for name in ("AsyncSolverServer", "faultinject", "deadline_missed",
                 "escalation ladder"):
        if name not in arch_text:
            errors.append(f"docs/ARCHITECTURE.md: async-serving "
                          f"dataflow name `{name}` unmentioned")
    async_fields = ("req_per_s", "p50_ms", "p99_ms", "deadline_miss_rate",
                    "reject_rate", "quarantined", "dispatch_batches",
                    "worst_healthy_err", "zero_lost", "n_violations")
    errors.extend(
        f"docs/BENCHMARKS.md: `serving_async` field `{n}` undocumented"
        for n in async_fields if f"`{n}`" not in bench_text)
    for name in ("--serving-async", "serving-chaos"):
        if name not in bench_text:
            errors.append(f"docs/BENCHMARKS.md: `{name}` undocumented")
    return errors


def check_readme_links_docs() -> list:
    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as f:
        text = f.read()
    return [f"README.md: does not link {d}" for d in CORE_DOCS
            if os.path.basename(d) not in text]


def main() -> int:
    errors = check_links() + check_options_documented() \
        + check_serving_documented() + check_scale_lane_documented() \
        + check_mixed_precision_documented() \
        + check_async_serving_documented() + check_readme_links_docs()
    for e in errors:
        print(f"docs-lint: {e}", file=sys.stderr)
    if not errors:
        n = len(DOC_FILES)
        print(f"docs-lint: OK ({n} files, all links + HyluOptions fields "
              "+ plan-cache/serving surface + corpus scale lane + "
              "mixed-precision surface + async-serving surface)")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
