"""AsyncSolverServer: event-loop continuous batching over SolverService.

``SolverService`` is a synchronous window: callers submit, someone calls
``flush()``, everyone waits.  A production mix of millions of small
requests needs the serving loop itself to decide *when* to dispatch —
trading batch fullness (throughput) against the oldest request's latency
budget — while refusing work it cannot absorb.  This module is that loop:

    submit(a, b, deadline_ms=…)        [asyncio coroutine → Future]
        │ admission: typed validation (InvalidRequestError taxonomy)
        │ backpressure: bounded per-group queue + global bound
        │   → full ⇒ immediate typed result (status="rejected",
        │     error.code="queue_full"); never an unbounded pileup
        ▼
    per-(fingerprint, RHS-shape) deques          ◄── flusher task wakes on:
        │                                            · a group reached
        ▼                                              batch_size
    dispatch thread (single worker)                  · the oldest request's
        service.solve_batch(window)                    deadline is within
        │  (validation, isolation, escalation          deadline_margin_ms
        ▼   ladder — see solver_service)             · max_linger_ms elapsed
    futures resolve with terminal SolveResult          since the oldest
    (latency_s + deadline_missed filled in)            request arrived

Design notes:

* **One dispatch worker.**  JAX dispatch is blocking and the engines are
  compiled per (pattern, batch_size); running dispatches on a single
  ``ThreadPoolExecutor`` worker keeps the event loop free to admit and
  reject while a batch computes, without oversubscribing the device.
* **Deadlines are soft.**  A request whose budget expires in the queue is
  *not* dropped — it dispatches in the next window and its result carries
  ``deadline_missed=True`` (and the miss is counted).  Dropping late work
  would violate the exactly-one-terminal-result contract.
* **Groups flush whole windows.**  When any trigger fires, every
  non-empty group queue is drained into one ``solve_batch`` call —
  ``SolverService`` re-groups by fingerprint internally, so cross-pattern
  batching costs nothing and the oldest request is always in the window
  that its trigger fired for.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.serve.solver_service import (SolverService, SolveRequest,
                                        SolveResult, SolveError,
                                        validate_request, ERR_QUEUE_FULL,
                                        STATUS_REJECTED, STATUS_SOLVED)


class _Pending:
    """One admitted request waiting in a group queue."""

    __slots__ = ("req", "future", "t_submit", "t_deadline")

    def __init__(self, req, future, t_submit, t_deadline):
        self.req = req
        self.future = future
        self.t_submit = t_submit      # monotonic seconds at admission
        self.t_deadline = t_deadline  # absolute monotonic deadline (or None)


class AsyncSolverServer:
    """Continuous-batching asyncio front-end for a :class:`SolverService`.

    service            — the synchronous SolverService to dispatch through
    max_queue_per_group — bounded depth of each (pattern, RHS-shape) queue;
                         admission control rejects (typed ``queue_full``)
                         beyond it
    max_pending        — global bound across all groups (second backpressure
                         tier, so many small groups cannot pile up
                         unboundedly either)
    deadline_margin_ms — flush a group when its oldest request's deadline is
                         within this margin (covers dispatch latency)
    max_linger_ms      — flush a non-empty window at most this long after
                         its oldest request arrived, even with no deadline
                         pressure (bounds latency for deadline-less traffic)
    default_deadline_ms — per-request latency budget applied when a submit
                         does not pass one (None = no deadline; falls back
                         to ``service.opts.deadline_ms``)

    Lifecycle: ``await server.start()`` … ``await server.stop()`` (drains by
    default), or ``async with AsyncSolverServer(...) as server:``.
    """

    def __init__(self, service: SolverService | None = None,
                 max_queue_per_group: int = 64,
                 max_pending: int = 256,
                 deadline_margin_ms: float = 5.0,
                 max_linger_ms: float = 50.0,
                 default_deadline_ms: float | None = None):
        self.service = service or SolverService()
        if max_queue_per_group < 1:
            raise ValueError(f"max_queue_per_group must be >= 1, got "
                             f"{max_queue_per_group}")
        if max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.max_queue_per_group = max_queue_per_group
        self.max_pending = max_pending
        self.deadline_margin_s = deadline_margin_ms / 1e3
        self.max_linger_s = max_linger_ms / 1e3
        if default_deadline_ms is None:
            default_deadline_ms = self.service.opts.deadline_ms
        self.default_deadline_ms = default_deadline_ms

        self._queues: dict[tuple, deque] = {}   # (fingerprint, tail) → deque
        self._n_pending = 0
        self._wake = None           # asyncio.Event, created in start()
        self._flusher = None        # the flusher task
        self._executor = None       # single-worker dispatch executor
        self._running = False
        self._latencies_ms: deque = deque(maxlen=4096)  # completed requests
        self.counters = dict(submitted=0, completed=0, rejected_full=0,
                             rejected_invalid=0, deadline_misses=0,
                             dispatch_batches=0)

    # ------------------------------------------------------------ lifecycle
    async def start(self):
        if self._running:
            return self
        self._running = True
        self._wake = asyncio.Event()
        self._executor = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="hylu-dispatch")
        self._flusher = asyncio.create_task(self._flush_loop())
        return self

    async def stop(self, drain: bool = True):
        """Stop the server.  With ``drain`` (default), every queued request
        is dispatched first — nothing admitted is ever lost; without it,
        queued requests resolve as rejected (``queue_full`` taxonomy code
        with ``detail["stage"]="shutdown"``)."""
        if not self._running:
            return
        if drain:
            while self._n_pending:
                await self._dispatch_window(self._drain_all())
        self._running = False
        self._wake.set()
        if self._flusher is not None:
            await self._flusher
            self._flusher = None
        if not drain:
            for p in self._drain_all():
                self._resolve(p, SolveResult(
                    status=STATUS_REJECTED, tag=p.req.tag,
                    error=SolveError(ERR_QUEUE_FULL,
                                     "server stopped without draining",
                                     dict(stage="shutdown"))))
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None

    async def __aenter__(self):
        return await self.start()

    async def __aexit__(self, *exc):
        await self.stop(drain=True)

    # ------------------------------------------------------------ admission
    async def submit(self, a, b, tag=None, factor_dtype=None,
                     deadline_ms: float | None = None) -> asyncio.Future:
        """Validate and enqueue one request; returns an ``asyncio.Future``
        resolving to this request's terminal :class:`SolveResult`.

        Raises :class:`InvalidRequestError` for an inadmissible request
        (malformed work is refused at the door, same contract as
        ``SolverService.submit``).  A full queue does NOT raise — the
        returned future resolves immediately with a typed
        ``status="rejected"`` / ``error.code="queue_full"`` result, so the
        caller always holds exactly one future per request and backpressure
        is data, not control flow."""
        if not self._running:
            raise RuntimeError("AsyncSolverServer is not running — use "
                               "'await server.start()' or 'async with'")
        a, b, err = validate_request(a, b)
        if err is not None:
            from repro.serve.solver_service import InvalidRequestError
            self.counters["rejected_invalid"] += 1
            raise InvalidRequestError(err)
        req = SolveRequest(a=a, b=b, tag=tag, factor_dtype=factor_dtype)

        loop = asyncio.get_running_loop()
        future = loop.create_future()
        key = self._group_key(req)
        q = self._queues.setdefault(key, deque())
        if len(q) >= self.max_queue_per_group or \
                self._n_pending >= self.max_pending:
            scope = ("group" if len(q) >= self.max_queue_per_group
                     else "global")
            self.counters["rejected_full"] += 1
            future.set_result(SolveResult(
                status=STATUS_REJECTED, tag=tag,
                error=SolveError(
                    ERR_QUEUE_FULL,
                    f"{scope} queue full "
                    f"(group depth {len(q)}/{self.max_queue_per_group}, "
                    f"pending {self._n_pending}/{self.max_pending})",
                    dict(scope=scope, group_depth=len(q),
                         max_queue_per_group=self.max_queue_per_group,
                         pending=self._n_pending,
                         max_pending=self.max_pending))))
            return future

        now = time.monotonic()
        if deadline_ms is None:
            deadline_ms = self.default_deadline_ms
        t_deadline = None if deadline_ms is None else now + deadline_ms / 1e3
        q.append(_Pending(req, future, now, t_deadline))
        self._n_pending += 1
        self.counters["submitted"] += 1
        self._wake.set()
        return future

    async def solve(self, a, b, tag=None, factor_dtype=None,
                    deadline_ms: float | None = None) -> SolveResult:
        """Submit one request and await its terminal result."""
        fut = await self.submit(a, b, tag=tag, factor_dtype=factor_dtype,
                                deadline_ms=deadline_ms)
        return await fut

    def _group_key(self, req: SolveRequest) -> tuple:
        from repro.core.options import plan_fingerprint
        opts = self.service._opts_for(req)
        return (plan_fingerprint(req.a, opts), req.b.shape[1:])

    # ---------------------------------------------------------- flush logic
    def _next_wakeup(self, now: float):
        """(flush_now, sleep_s): whether any trigger has fired, and how long
        the flusher may sleep before the earliest future trigger."""
        flush = False
        sleep_s = None
        bs = self.service.batch_size
        for q in self._queues.values():
            if not q:
                continue
            if bs is not None and len(q) >= bs:
                flush = True
                break
            head = q[0]
            triggers = [head.t_submit + self.max_linger_s]
            if head.t_deadline is not None:
                triggers.append(head.t_deadline - self.deadline_margin_s)
            t_fire = min(triggers)
            if t_fire <= now:
                flush = True
                break
            dt = t_fire - now
            sleep_s = dt if sleep_s is None else min(sleep_s, dt)
        return flush, sleep_s

    def _drain_all(self) -> list:
        window = []
        for q in self._queues.values():
            window.extend(q)
            q.clear()
        self._n_pending = 0
        return window

    async def _flush_loop(self):
        while self._running:
            flush, sleep_s = self._next_wakeup(time.monotonic())
            if flush:
                await self._dispatch_window(self._drain_all())
                continue
            self._wake.clear()
            # re-check after clearing: a submit may have raced the clear
            flush, sleep_s = self._next_wakeup(time.monotonic())
            if flush:
                continue
            try:
                await asyncio.wait_for(self._wake.wait(), timeout=sleep_s)
            except asyncio.TimeoutError:
                pass

    async def _dispatch_window(self, window: list):
        if not window:
            return
        loop = asyncio.get_running_loop()
        reqs = [p.req for p in window]
        self.counters["dispatch_batches"] += 1
        try:
            results = await loop.run_in_executor(
                self._executor, self.service.solve_batch, reqs)
        except BaseException as e:  # noqa: BLE001 — never lose a window
            from repro.serve.solver_service import (SolveError, SolveResult,
                                                    ERR_DISPATCH,
                                                    STATUS_FAILED)
            results = [SolveResult(
                status=STATUS_FAILED, tag=r.tag,
                error=SolveError(ERR_DISPATCH,
                                 f"window dispatch raised "
                                 f"{type(e).__name__}: {e}",
                                 dict(stage="window")))
                for r in reqs]
        for p, r in zip(window, results):
            self._resolve(p, r)

    def _resolve(self, p: _Pending, result: SolveResult):
        now = time.monotonic()
        result.latency_s = now - p.t_submit
        if p.t_deadline is not None and now > p.t_deadline:
            result.deadline_missed = True
            self.counters["deadline_misses"] += 1
        self.counters["completed"] += 1
        if result.status != STATUS_REJECTED:
            # admission rejections are instant — keeping them out of the
            # latency record stops rejects from faking a fast p50
            self._latencies_ms.append(result.latency_s * 1e3)
        if not p.future.done():
            p.future.get_loop().call_soon_threadsafe(
                _set_result_safe, p.future, result)

    # ------------------------------------------------------------ telemetry
    def stats(self) -> dict:
        """Structured serving stats: queue depth, latency percentiles,
        deadline-miss / reject rates, and the underlying service's
        dispatch counters."""
        lat = np.asarray(self._latencies_ms, dtype=np.float64)
        completed = max(1, self.counters["completed"])
        return dict(
            queue_depth=self._n_pending,
            n_groups=sum(1 for q in self._queues.values() if q),
            submitted=self.counters["submitted"],
            completed=self.counters["completed"],
            dispatch_batches=self.counters["dispatch_batches"],
            p50_ms=float(np.percentile(lat, 50)) if lat.size else None,
            p99_ms=float(np.percentile(lat, 99)) if lat.size else None,
            deadline_miss_rate=self.counters["deadline_misses"] / completed,
            reject_rate=(self.counters["rejected_full"]
                         + self.counters["rejected_invalid"])
                        / max(1, self.counters["submitted"]
                              + self.counters["rejected_full"]
                              + self.counters["rejected_invalid"]),
            rejected_full=self.counters["rejected_full"],
            rejected_invalid=self.counters["rejected_invalid"],
            deadline_misses=self.counters["deadline_misses"],
            retries=self.service.stats["retries"],
            quarantined=self.service.stats["quarantined"],
            failed=self.service.stats["failed"],
            service=dict(self.service.stats),
        )


def _set_result_safe(future, result):
    if not future.done():
        future.set_result(result)
