"""Serving steps: prefill (build KV caches, return last-token logits) and
decode (one token against the cache).  Both are pure and jit-able; the
launcher applies shardings.  Batched requests = the batch dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def make_prefill_step(cfg: ArchConfig, s_max: int | None = None):
    """prefill(params, tokens/embeds/positions) -> (last_logits, cache).
    Cache is padded to s_max capacity (defaults to prompt length)."""

    def prefill(params, tokens=None, embeds=None, positions=None):
        hidden, _, caches = T.forward(cfg, params, tokens=tokens,
                                      embeds=embeds, positions=positions,
                                      collect_cache=True)
        logits = T.lm_logits(cfg, params, hidden[:, -1:, :])
        if s_max is not None:
            kinds = cfg.layer_kinds()

            def pad_kv(leaf):             # (np, B, S, Hkv, hd) → capacity
                s = leaf.shape[2]
                if s < s_max:
                    return jnp.pad(leaf, ((0, 0), (0, 0), (0, s_max - s),
                                          (0, 0), (0, 0)))
                return leaf

            caches = [jax.tree.map(pad_kv, c) if kinds[i] == "attn" else c
                      for i, c in enumerate(caches)]
        return logits, caches

    return prefill


def make_decode_step(cfg: ArchConfig):
    """decode(params, tokens, cache, pos, [embeds, positions]) ->
    (logits (B,1,V), new cache)."""

    def decode(params, tokens, cache, pos, embeds=None, positions=None):
        return T.decode_step(cfg, params, tokens, cache, pos,
                             embeds=embeds, positions=positions)

    return decode


def greedy_generate(cfg: ArchConfig, params, prompt_tokens, n_new: int,
                    s_max: int | None = None):
    """Simple host-driven greedy loop (example/testing utility)."""
    b, s = prompt_tokens.shape
    s_max = s_max or (s + n_new)
    prefill = make_prefill_step(cfg, s_max=s_max)
    decode = jax.jit(make_decode_step(cfg))
    logits, cache = prefill(params, tokens=prompt_tokens)
    out = [jnp.argmax(logits[:, -1, :], axis=-1)]
    pos = s
    for _ in range(n_new - 1):
        logits, cache = decode(params, out[-1][:, None], cache,
                               jnp.asarray(pos, jnp.int32))
        out.append(jnp.argmax(logits[:, -1, :], axis=-1))
        pos += 1
    return jnp.stack(out, axis=1)
