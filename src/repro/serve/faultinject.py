"""Fault-injection harness for the serving tier.

Serving robustness is a property you *demonstrate*, not assert: this
module builds mixed-pattern request streams laced with every fault class
the taxonomy names, drives them through an :class:`AsyncSolverServer`,
and checks the contract the ISSUE states — **zero lost requests, zero
silently-wrong results**, healthy requests matching an independent fp64
oracle even when their batch neighbors are poisoned.

The harness is deliberately independent of the solver stack's own
numerics: the oracle is a dense ``np.linalg.solve`` on the fp64 values,
so a bug that corrupts both the engine and its residual reporting still
gets caught.

Fault matrix (``FAULT_KINDS``):

====================  =====================================================
kind                  what is injected → expected terminal outcome
====================  =====================================================
``nan_values``        NaN in the matrix values → rejected at admission
                      (``nonfinite_values``)
``inf_values``        Inf in the matrix values → rejected
                      (``nonfinite_values``)
``nan_rhs``           NaN in the RHS → rejected (``nonfinite_rhs``)
``wrong_shape_rhs``   RHS of the wrong length → rejected
                      (``shape_mismatch``)
``singular_values``   a structurally-fine pattern whose values zero out a
                      row → numerically singular; survives admission, must
                      come back quarantined/failed, never as silent garbage
``ill_conditioned``   diagonal scaled across ~12 orders of magnitude →
                      solved (refinement earns it) or honestly quarantined
``tiny_deadline``     healthy system with a microscopic latency budget →
                      still solved; only ``deadline_missed`` may be set
====================  =====================================================

Use :func:`make_stream` to build a reproducible stream,
:func:`run_stream` to drive it, and :func:`check_report` to turn the
outcome into a list of contract violations (empty = pass).  The chaos
test suite (``tests/test_fault_injection.py``), the ``launch/serve.py``
load generator, and the ``--serving-async`` benchmark all share this one
harness, so "what the CI gate proves" and "what the benchmark measures"
cannot drift apart.
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core.matrix import CSR
from repro.serve.solver_service import (InvalidRequestError, STATUS_SOLVED,
                                        STATUS_REJECTED, STATUS_FAILED,
                                        STATUS_QUARANTINED,
                                        TERMINAL_STATUSES)

# every injected fault kind; ``make_stream`` interleaves all of them
FAULT_KINDS = ("nan_values", "inf_values", "nan_rhs", "wrong_shape_rhs",
               "singular_values", "ill_conditioned", "tiny_deadline")

# how tightly healthy requests must match the dense fp64 oracle
ORACLE_RTOL = 1e-10

PATTERNS = ("circuit", "banded", "denseish")


# ------------------------------------------------------------- test systems
def build_pattern(name: str, n: int = 32, seed: int = 0) -> CSR:
    """A structurally-nonsingular CSR with healthy (diagonally dominant,
    well-conditioned) values.  Three pattern families keep the stream
    genuinely mixed-pattern: 'circuit' (sparse random + diagonal),
    'banded' (tridiagonal + sparse long-range), 'denseish' (~20% fill)."""
    # zlib.crc32, not hash(): str hashing is salted per process, and the
    # streams must be bit-reproducible across runs
    rng = np.random.default_rng(seed * 1000 + zlib.crc32(name.encode()))
    rows: list[np.ndarray] = []
    for i in range(n):
        if name == "circuit":
            k = int(rng.integers(1, 4))
            cols = rng.choice(n, size=k, replace=False)
        elif name == "banded":
            cols = np.array([c for c in (i - 1, i + 1) if 0 <= c < n])
            if rng.random() < 0.2:
                cols = np.append(cols, rng.integers(0, n))
        elif name == "denseish":
            k = max(2, n // 5)
            cols = rng.choice(n, size=k, replace=False)
        else:
            raise ValueError(f"unknown pattern family {name!r}; "
                             f"expected one of {PATTERNS}")
        rows.append(np.unique(np.append(cols, i)))  # always keep the diagonal
    indptr = np.zeros(n + 1, dtype=np.int64)
    indptr[1:] = np.cumsum([len(r) for r in rows])
    indices = np.concatenate(rows).astype(np.int64)
    data = np.empty(indptr[-1], dtype=np.float64)
    for i in range(n):
        s, e = indptr[i], indptr[i + 1]
        vals = rng.uniform(-1.0, 1.0, size=e - s)
        diag = int(np.searchsorted(indices[s:e], i))
        vals[diag] = np.abs(vals).sum() + 1.0 + rng.uniform(0.0, 1.0)
        data[s:e] = vals
    return CSR(n=n, indptr=indptr, indices=indices, data=data)


def healthy_values(pattern: CSR, seed: int) -> np.ndarray:
    """A fresh healthy value set on an existing pattern (same structure,
    diagonally dominant): the per-request values of the stream."""
    rng = np.random.default_rng(seed)
    data = rng.uniform(-1.0, 1.0, size=pattern.nnz)
    for i in range(pattern.n):
        s, e = pattern.indptr[i], pattern.indptr[i + 1]
        diag = s + int(np.searchsorted(pattern.indices[s:e], i))
        data[diag] = np.abs(data[s:e]).sum() + 1.0 + rng.uniform(0.0, 1.0)
    return data


def fp64_oracle(a: CSR, b: np.ndarray) -> np.ndarray:
    """Dense fp64 reference solution — deliberately independent of the
    whole solver stack (ordering, factorization, refinement)."""
    return np.linalg.solve(a.to_dense().astype(np.float64),
                           np.asarray(b, dtype=np.float64))


# ------------------------------------------------------------- fault stream
@dataclasses.dataclass
class Injected:
    """One stream element: the request plus the contract it must satisfy.

    kind        — a ``FAULT_KINDS`` member, or None for healthy traffic
    expect      — the set of admissible terminal statuses for this request
    oracle_x    — dense fp64 reference (healthy requests only)
    deadline_ms — per-request latency budget override (tiny_deadline)"""
    a: CSR
    b: np.ndarray
    kind: str | None = None
    expect: tuple = (STATUS_SOLVED,)
    oracle_x: np.ndarray | None = None
    deadline_ms: float | None = None
    tag: object = None


def _with_values(pattern: CSR, data: np.ndarray) -> CSR:
    return CSR(n=pattern.n, indptr=pattern.indptr, indices=pattern.indices,
               data=data)


def inject(kind: str, pattern: CSR, seed: int, tag=None) -> Injected:
    """Build one faulty request of the given kind on ``pattern``."""
    rng = np.random.default_rng(seed)
    data = healthy_values(pattern, seed)
    n = pattern.n
    b = rng.standard_normal(n)
    if kind == "nan_values":
        data = data.copy()
        data[rng.integers(0, data.size)] = np.nan
        return Injected(_with_values(pattern, data), b, kind,
                        expect=(STATUS_REJECTED,), tag=tag)
    if kind == "inf_values":
        data = data.copy()
        data[rng.integers(0, data.size)] = np.inf
        return Injected(_with_values(pattern, data), b, kind,
                        expect=(STATUS_REJECTED,), tag=tag)
    if kind == "nan_rhs":
        b = b.copy()
        b[rng.integers(0, n)] = np.nan
        return Injected(_with_values(pattern, data), b, kind,
                        expect=(STATUS_REJECTED,), tag=tag)
    if kind == "wrong_shape_rhs":
        return Injected(_with_values(pattern, data),
                        rng.standard_normal(n + 3), kind,
                        expect=(STATUS_REJECTED,), tag=tag)
    if kind == "singular_values":
        data = data.copy()
        row = n // 2
        data[pattern.indptr[row]:pattern.indptr[row + 1]] = 0.0
        return Injected(_with_values(pattern, data), b, kind,
                        expect=(STATUS_QUARANTINED, STATUS_FAILED), tag=tag)
    if kind == "ill_conditioned":
        data = data.copy()
        scale = np.logspace(0, -12, n)   # rows span ~12 orders of magnitude
        for i in range(n):
            s, e = pattern.indptr[i], pattern.indptr[i + 1]
            data[s:e] *= scale[i]
        return Injected(_with_values(pattern, data), b, kind,
                        expect=(STATUS_SOLVED, STATUS_QUARANTINED), tag=tag)
    if kind == "tiny_deadline":
        a = _with_values(pattern, data)
        return Injected(a, b, kind, expect=(STATUS_SOLVED,),
                        oracle_x=fp64_oracle(a, b), deadline_ms=1e-3,
                        tag=tag)
    raise ValueError(f"unknown fault kind {kind!r}; "
                     f"expected one of {FAULT_KINDS}")


def make_stream(n_requests: int, fault_rate: float = 0.25, seed: int = 0,
                n: int = 32, multi_rhs_rate: float = 0.15,
                kinds=FAULT_KINDS) -> list:
    """A reproducible mixed-pattern stream of ``n_requests`` elements:
    healthy diag-dominant systems across the three pattern families, with
    ``fault_rate`` of the stream replaced by faults cycling through
    ``kinds``.  Healthy requests carry their dense-fp64 oracle solution;
    a ``multi_rhs_rate`` fraction use an (n, 2) RHS to exercise the
    RHS-shape grouping axis."""
    rng = np.random.default_rng(seed)
    patterns = {name: build_pattern(name, n=n, seed=seed)
                for name in PATTERNS}
    stream: list = []
    fault_i = 0
    for i in range(n_requests):
        pat = patterns[PATTERNS[int(rng.integers(0, len(PATTERNS)))]]
        if rng.random() < fault_rate:
            kind = kinds[fault_i % len(kinds)]
            fault_i += 1
            stream.append(inject(kind, pat, seed=seed * 7919 + i,
                                 tag=("fault", kind, i)))
            continue
        a = _with_values(pat, healthy_values(pat, seed * 7919 + i))
        if rng.random() < multi_rhs_rate:
            b = rng.standard_normal((pat.n, 2))
        else:
            b = rng.standard_normal(pat.n)
        stream.append(Injected(a, b, kind=None, expect=(STATUS_SOLVED,),
                               oracle_x=fp64_oracle(a, b),
                               tag=("healthy", i)))
    return stream


# --------------------------------------------------------------- the driver
async def run_stream(server, stream, warmup: bool = True) -> dict:
    """Drive ``stream`` through an (already started) AsyncSolverServer and
    return a structured report.  With ``warmup`` (default), one healthy
    request per distinct pattern is solved first so cold-path analysis is
    seeded by healthy values, mirroring a warmed production server.

    Every stream element is accounted for exactly once: requests the
    server refuses at admission (``InvalidRequestError``) are recorded as
    rejected outcomes; everything else resolves through its future.  The
    report's ``lost`` field is ``len(stream) - outcomes`` — the
    exactly-one-terminal-result contract reduced to one number."""
    warm_seen: set = set()
    if warmup:
        for item in stream:
            if item.kind is not None:
                continue
            key = (id(item.a.indptr), item.b.shape[1:])
            if key in warm_seen:
                continue
            warm_seen.add(key)
            await server.solve(item.a, item.b, tag=("warmup",))

    outcomes: list = []   # (item, status, error_code, result-or-None)
    futures: list = []    # (item, future)
    for item in stream:
        try:
            fut = await server.submit(item.a, item.b, tag=item.tag,
                                      deadline_ms=item.deadline_ms)
        except InvalidRequestError as e:
            outcomes.append((item, STATUS_REJECTED, e.error.code, None))
            continue
        futures.append((item, fut))
    for item, fut in futures:
        r = await fut
        outcomes.append((item, r.status,
                         r.error.code if r.error is not None else None, r))

    by_status: dict = {s: 0 for s in TERMINAL_STATUSES}
    violations: list = []
    worst_healthy_err = 0.0
    n_healthy_checked = 0
    for item, status, code, r in outcomes:
        by_status[status] = by_status.get(status, 0) + 1
        if status not in TERMINAL_STATUSES:
            violations.append(f"non-terminal status {status!r} for "
                              f"tag={item.tag}")
        if status not in item.expect:
            violations.append(
                f"kind={item.kind or 'healthy'} tag={item.tag}: got "
                f"status={status} (error={code}), expected one of "
                f"{item.expect}")
        if status == STATUS_SOLVED and r is not None:
            if r.x is None or not np.all(np.isfinite(np.asarray(r.x))):
                violations.append(f"tag={item.tag}: status=solved but the "
                                  f"solution is missing or non-finite — "
                                  f"silent garbage")
            elif item.oracle_x is not None:
                err = (np.abs(np.asarray(r.x) - item.oracle_x).max()
                       / max(np.abs(item.oracle_x).max(), 1.0))
                worst_healthy_err = max(worst_healthy_err, float(err))
                n_healthy_checked += 1
                if err > ORACLE_RTOL:
                    violations.append(
                        f"tag={item.tag}: healthy request diverged from the "
                        f"fp64 oracle (rel err {err:.3e} > {ORACLE_RTOL:g})")
    return dict(
        n_requests=len(stream),
        n_outcomes=len(outcomes),
        lost=len(stream) - len(outcomes),
        by_status=by_status,
        worst_healthy_err=worst_healthy_err,
        n_healthy_checked=n_healthy_checked,
        violations=violations,
        server_stats=server.stats(),
    )


def check_report(report: dict) -> list:
    """The serving robustness contract as a list of violations (empty =
    pass): zero lost requests, zero silently-wrong results, healthy
    fp64-oracle parity, and per-kind expected terminal statuses."""
    violations = list(report["violations"])
    if report["lost"] != 0:
        violations.insert(0, f"{report['lost']} request(s) received no "
                             f"terminal result — losses")
    return violations
