"""SolverService: mixed-pattern serving on top of the batched engines.

The batched repeated-solve path (PRs 1–4) factors and solves K systems of
ONE sparsity pattern as pre-compiled XLA programs.  Production traffic is
not that polite: a stream of requests mixes circuit, banded, unsymmetric,
… patterns arbitrarily.  This module is the dispatcher that makes the
mixed stream look like per-pattern batches:

    requests (a_i, b_i)  ──fingerprint──►  groups by plan_fingerprint
        │                                      │  chunk + pad to batch_size
        ▼                                      ▼
    PlanCache (memory → checkpoints/ → analyze)   factor_batched+solve_batched
        │                                      │
        └── Analysis + compiled engines        └── scatter back to
                                                   request order

Padding uses the engines' existing alive-masking: padded systems replicate
the chunk's first value set with a zero RHS (they converge on refinement
iteration 0 and are sliced away), so every (pattern, batch_size) pair
compiles exactly ONE XLA program no matter how group sizes fluctuate.
Per-request results are bit-identical to running that request's pattern
group through ``factor_batched``/``solve_batched`` directly — batching and
padding never change per-system numerics.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.matrix import CSR
from repro.core.options import (HyluOptions, plan_fingerprint, np_dtype,
                                resolve_dtype_names)
from repro.core.plan_cache import PlanCache, DEFAULT_CACHE_DIR
from repro.core.batched import factor_batched, solve_batched


@dataclasses.dataclass
class SolveRequest:
    """One serving request: solve ``a x = b`` for this request's matrix.

    a    — CSR (pattern + values); anything with ``tocsr()`` is converted
    b    — (n,) right-hand side or (n, m) multi-RHS
    tag  — opaque caller id, passed through to the result
    factor_dtype — per-request precision routing: None uses the service's
           options template; a dtype name ("float32"/"float64"/"bfloat16")
           overrides it for this request.  The dtype is part of the plan
           fingerprint, so mixed-precision traffic groups into separate
           dispatches per dtype automatically."""
    a: CSR
    b: np.ndarray
    tag: object = None
    factor_dtype: str | None = None


@dataclasses.dataclass
class SolveResult:
    """Per-request outcome, in the original request order."""
    x: np.ndarray              # (n,) or (n, m)
    residual: object           # float or (m,) — scaled 1-norm residual(s)
    n_refine: int              # accepted refinement steps for this system
    n_perturb: int             # pivot perturbations in this factorization
    fingerprint: str           # the plan-cache key this request hit
    group_size: int            # how many requests shared the dispatch group
    tag: object = None
    refine_failed: bool = False   # refinement exited above tolerance (after
                                  # any fp64 fallback redo) — an honest
                                  # per-request quality flag
    factor_dtype: str = "float64"  # precision this request was factored in


def _as_csr(a) -> CSR:
    if isinstance(a, CSR):
        return a
    if hasattr(a, "tocsr"):
        return CSR.from_scipy(a.tocsr())
    raise TypeError(f"request matrix must be a CSR (or scipy sparse), got "
                    f"{type(a).__name__}")


class SolverService:
    """Front-end for heterogeneous (pattern, values, b) solve traffic.

    opts           — HyluOptions template applied to every request (mesh,
                     refinement, kernel thresholds, …)
    cache          — a PlanCache to share across services; built from
                     cache_dir/cache_capacity when None
    cache_dir      — artifact-store directory for the internally-built
                     cache (None disables disk persistence; the default
                     sentinel resolves under ``opts.cache_root`` /
                     ``$HYLU_CACHE_ROOT`` / the repo's ``checkpoints``
                     dir — see ``repro.core.plan_cache.resolve_cache_dir``)
    cache_capacity — LRU bound of the internally-built cache
    batch_size     — fixed dispatch batch: every group is chunked and
                     padded up to this many systems, so each pattern
                     compiles ONE batched program regardless of how the
                     traffic mix fluctuates; None dispatches each group at
                     its natural size (one compile per distinct group size)

    Use ``solve_batch(requests)`` for one-shot dispatch, or
    ``submit(a, b)`` + ``flush()`` to accumulate a serving window first.
    """

    def __init__(self, opts: HyluOptions | None = None,
                 cache: PlanCache | None = None,
                 cache_dir: str | None = DEFAULT_CACHE_DIR,
                 cache_capacity: int = 32,
                 batch_size: int | None = 8):
        self.opts = opts or HyluOptions()
        self.cache = cache if cache is not None else PlanCache(
            capacity=cache_capacity, directory=cache_dir,
            cache_root=self.opts.cache_root)
        if batch_size is not None and batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = batch_size
        self.stats = dict(requests=0, groups=0, dispatches=0,
                          padded_systems=0, patterns_seen=0, solve_s=0.0,
                          refine_failed=0, fp64_fallbacks=0)
        self._pattern_modes: dict[str, str] = {}   # fingerprint → kernel mode
        self._pending: list[SolveRequest] = []

    # ---------------------------------------------------------------- queue
    def submit(self, a, b, tag=None) -> int:
        """Enqueue one request; returns its position in the next flush."""
        self._pending.append(SolveRequest(a=_as_csr(a), b=np.asarray(b),
                                          tag=tag))
        return len(self._pending) - 1

    def flush(self) -> list:
        """Dispatch every queued request; results in submit order.  The
        queue is cleared only after the dispatch returns — a request that
        fails validation leaves the whole window queued (fix or drop it,
        then flush again) instead of silently discarding the rest."""
        results = self.solve_batch(self._pending)
        self._pending = []
        return results

    # ------------------------------------------------------------- dispatch
    def solve_batch(self, requests) -> list:
        """Group a heterogeneous request list by plan fingerprint, dispatch
        each group through the cached batched engines, and scatter results
        back to request order.  Requests may be ``SolveRequest`` objects or
        bare ``(a, b)`` pairs.  Returns ``list[SolveResult]`` aligned with
        ``requests``."""
        reqs = []
        for r in requests:
            if not isinstance(r, SolveRequest):
                a, b = r
                r = SolveRequest(a=a, b=b)
            a = _as_csr(r.a)
            # keep the submitted precision here — the dispatch stages the
            # whole chunk in the engine's staging dtype in one cast, instead
            # of the old unconditional fp64 upcast + second copy
            b = np.asarray(r.b)
            if b.ndim not in (1, 2) or b.shape[0] != a.n:
                raise ValueError(
                    f"request RHS shape {b.shape} does not match its "
                    f"matrix (n={a.n}; expected (n,) or (n, m))")
            reqs.append(SolveRequest(a=a, b=b, tag=r.tag,
                                     factor_dtype=r.factor_dtype))
        t0 = time.perf_counter()

        # group by (fingerprint, RHS tail shape), preserving request order
        # within each group; differing multi-RHS widths of one pattern
        # dispatch separately (the batched RHS must be rectangular).
        # factor_dtype is a PLAN_OPTION_FIELDS member, so a per-request
        # dtype override lands in a different fingerprint — mixed-precision
        # traffic routes into separate groups with no extra machinery
        groups: dict[tuple, list[int]] = {}
        group_opts: dict[str, HyluOptions] = {}
        for i, r in enumerate(reqs):
            opts_i = (self.opts if r.factor_dtype is None else
                      dataclasses.replace(self.opts,
                                          factor_dtype=r.factor_dtype))
            fp = plan_fingerprint(r.a, opts_i)
            group_opts[fp] = opts_i
            groups.setdefault((fp, r.b.shape[1:]), []).append(i)

        results: list = [None] * len(reqs)
        for (fp, _tail), idxs in groups.items():
            if fp not in self._pattern_modes:
                self.stats["patterns_seen"] += 1
            self.stats["groups"] += 1
            an = self.cache.get_or_analyze(reqs[idxs[0]].a, group_opts[fp],
                                           fingerprint=fp)
            self._pattern_modes[fp] = an.choice.mode
            step = self.batch_size or len(idxs)
            for c0 in range(0, len(idxs), step):
                chunk = idxs[c0:c0 + step]
                self._dispatch(an, fp, reqs, chunk, pad_to=step,
                               group_size=len(idxs), results=results)

        self.stats["requests"] += len(reqs)
        self.stats["solve_s"] += time.perf_counter() - t0
        return results

    def _dispatch(self, an, fp, reqs, chunk, pad_to, group_size, results):
        """One padded batched factor+solve for ``chunk`` (request indices
        of one pattern/RHS-shape group), scattered into ``results``."""
        import jax

        g = len(chunk)
        k = max(pad_to, g)
        a0 = reqs[chunk[0]].a
        # stage in the engine's staging (= refine) dtype: fp64 for pure-fp64
        # and mixed reduced-factor engines, the factor dtype for a pure
        # reduced-precision engine — one cast, no fp64 detour
        _, rname = resolve_dtype_names(an.opts, jax.config.jax_enable_x64)
        sdt = np_dtype(rname)
        vb = np.empty((k, a0.nnz), dtype=sdt)
        bb = np.zeros((k,) + reqs[chunk[0]].b.shape, dtype=sdt)
        for j, i in enumerate(chunk):
            vb[j] = reqs[i].a.data
            bb[j] = reqs[i].b
        # pad with the chunk's first system + zero RHS: well-conditioned,
        # converges on iteration 0 under the per-system alive-masking
        vb[g:] = vb[0]

        bst = factor_batched(an, (a0.indptr, a0.indices), vb)
        x, info = solve_batched(bst, bb)
        self.stats["dispatches"] += 1
        self.stats["padded_systems"] += k - g
        self.stats["fp64_fallbacks"] += int(info.get("n_fp64_fallback", 0))
        failed = np.asarray(info["refine_failed"])
        for j, i in enumerate(chunk):
            req_failed = bool(np.any(failed[j]))
            self.stats["refine_failed"] += int(req_failed)
            results[i] = SolveResult(
                x=x[j],
                residual=(float(info["residual"][j])
                          if np.ndim(info["residual"][j]) == 0
                          else np.asarray(info["residual"][j])),
                n_refine=int(info["n_refine_per_system"][j].max()
                             if np.ndim(info["n_refine_per_system"][j])
                             else info["n_refine_per_system"][j]),
                n_perturb=int(info["n_perturb"][j]),
                fingerprint=fp, group_size=group_size, tag=reqs[i].tag,
                refine_failed=req_failed,
                factor_dtype=info["factor_dtype"])

    # ------------------------------------------------------------ introspect
    @property
    def pattern_modes(self) -> dict:
        """fingerprint → kernel mode chosen for that pattern (rowrow /
        hybrid / supernodal) — the routing record tests assert on."""
        return dict(self._pattern_modes)
