"""SolverService: mixed-pattern serving on top of the batched engines.

The batched repeated-solve path (PRs 1–4) factors and solves K systems of
ONE sparsity pattern as pre-compiled XLA programs.  Production traffic is
not that polite: a stream of requests mixes circuit, banded, unsymmetric,
… patterns arbitrarily.  This module is the dispatcher that makes the
mixed stream look like per-pattern batches:

    requests (a_i, b_i)  ──validate──►  typed rejection | accepted
        │                                      │  group by plan_fingerprint
        ▼                                      ▼  chunk + pad to batch_size
    PlanCache (memory → checkpoints/ → analyze)   factor_batched+solve_batched
        │                                      │
        └── Analysis + compiled engines        └── scatter back to
                                                   request order

Padding uses the engines' existing alive-masking: padded systems replicate
the chunk's first value set with a zero RHS (they converge on refinement
iteration 0 and are sliced away), so every (pattern, batch_size) pair
compiles exactly ONE XLA program no matter how group sizes fluctuate.
Per-request results are bit-identical to running that request's pattern
group through ``factor_batched``/``solve_batched`` directly — batching and
padding never change per-system numerics.

Fault tolerance (the serving robustness contract):

* **Admission validation** — every request is validated before it can
  reach a batch (:func:`validate_request`): matrix type/shape, real
  floating dtypes, finite values/RHS, RHS shape, structural
  non-singularity.  ``solve_batch`` turns a failed validation into a
  typed per-request result (``status="rejected"``, ``error.code`` from
  the taxonomy below); ``submit`` raises :class:`InvalidRequestError`
  immediately so a malformed request never poisons the queued window.
* **Error isolation** — each pattern group's analyze and each chunk's
  dispatch run under their own exception barrier: a raise marks *that*
  group's requests ``status="failed"`` (``error.code="dispatch_error"``,
  with the stage and exception in ``error.detail``) and every other
  group's results are returned untouched.  ``solve_batch`` never loses a
  window and never raises because of one bad request.
* **Escalation ladder** — a request whose refinement exits above
  tolerance (after the engine-level fp64 fallback of
  ``core.batched.solve_batched``) is re-dispatched up to
  ``opts.retry_max`` times with a boosted pivot-perturbation threshold
  (``options.resolve_retry_perturb``; a distinct plan fingerprint, so
  retries never touch the healthy traffic's engines).  What still fails
  is returned ``status="quarantined"`` with diagnostics in
  ``error.detail`` — the honest terminal outcome; quarantined ``x`` is
  the best attempt, flagged untrustworthy.

Every request therefore receives exactly one terminal result:
``solved`` | ``rejected`` | ``failed`` | ``quarantined``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.matrix import CSR
from repro.core.options import (HyluOptions, plan_fingerprint, np_dtype,
                                resolve_dtype_names, resolve_retry_perturb)
from repro.core.plan_cache import PlanCache, DEFAULT_CACHE_DIR
from repro.core.batched import factor_batched, solve_batched


# ------------------------------------------------------------ error taxonomy
# Admission-time rejections — the request never reaches a batch:
ERR_BAD_MATRIX = "bad_matrix"              # not a CSR / nothing with tocsr()
ERR_BAD_DTYPE = "bad_dtype"                # values/RHS not real numeric
ERR_NONFINITE_VALUES = "nonfinite_values"  # NaN/Inf in the matrix values
ERR_NONFINITE_RHS = "nonfinite_rhs"        # NaN/Inf in the right-hand side
ERR_SHAPE_MISMATCH = "shape_mismatch"      # RHS shape incompatible with n
ERR_SINGULAR_PATTERN = "singular_pattern"  # structurally singular pattern
                                           # (empty row or column)
ERR_QUEUE_FULL = "queue_full"              # async admission control: bounded
                                           # queue is full (backpressure)
# Dispatch-time failure — the request's pattern group raised:
ERR_DISPATCH = "dispatch_error"
# Post-ladder quarantine — dispatched, but never reached tolerance:
ERR_QUARANTINED = "quarantined"

# Terminal statuses: every request gets exactly one result in exactly one
# of these states.
STATUS_SOLVED = "solved"            # dispatched, refinement at tolerance
STATUS_REJECTED = "rejected"        # refused at admission (typed error)
STATUS_FAILED = "failed"            # its group's dispatch raised
STATUS_QUARANTINED = "quarantined"  # dispatched; tolerance unreachable even
                                    # after the full escalation ladder
TERMINAL_STATUSES = (STATUS_SOLVED, STATUS_REJECTED, STATUS_FAILED,
                     STATUS_QUARANTINED)


@dataclasses.dataclass
class SolveError:
    """Typed per-request error: a taxonomy ``code`` (the ``ERR_*``
    constants), a human-readable ``message``, and a ``detail`` dict of
    structured diagnostics (offending index, residual, retry count, …)."""
    code: str
    message: str
    detail: dict = dataclasses.field(default_factory=dict)


class InvalidRequestError(ValueError):
    """Raised by ``SolverService.submit`` when a request fails admission
    validation — carries the typed ``SolveError`` as ``.error`` so callers
    can branch on ``error.code`` instead of parsing the message."""

    def __init__(self, error: SolveError):
        super().__init__(f"{error.code}: {error.message}")
        self.error = error


@dataclasses.dataclass
class SolveRequest:
    """One serving request: solve ``a x = b`` for this request's matrix.

    a    — CSR (pattern + values); anything with ``tocsr()`` is converted
    b    — (n,) right-hand side or (n, m) multi-RHS
    tag  — opaque caller id, passed through to the result
    factor_dtype — per-request precision routing: None uses the service's
           options template; a dtype name ("float32"/"float64"/"bfloat16")
           overrides it for this request.  The dtype is part of the plan
           fingerprint, so mixed-precision traffic groups into separate
           dispatches per dtype automatically."""
    a: CSR
    b: np.ndarray
    tag: object = None
    factor_dtype: str | None = None


@dataclasses.dataclass
class SolveResult:
    """Per-request terminal outcome, in the original request order.

    ``status`` is one of ``TERMINAL_STATUSES``; anything except
    ``"solved"`` carries a typed ``error`` and (for rejected/failed
    requests) ``x=None``.  Quarantined results keep the best-attempt ``x``
    for diagnostics, explicitly flagged untrustworthy."""
    x: np.ndarray | None = None  # solution; None for rejected/failed
    residual: object = None      # float or (m,) — scaled 1-norm residual(s)
    n_refine: int = 0            # accepted refinement steps for this system
    n_perturb: int = 0           # pivot perturbations in this factorization
    fingerprint: str = ""        # the plan-cache key this request hit
    group_size: int = 0          # how many requests shared the dispatch group
    tag: object = None
    refine_failed: bool = False   # refinement exited above tolerance (after
                                  # any fp64 fallback redo) — an honest
                                  # per-request quality flag
    factor_dtype: str = "float64"  # precision this request was factored in
    status: str = STATUS_SOLVED    # terminal state (TERMINAL_STATUSES)
    error: SolveError | None = None  # typed error for non-solved statuses
    n_retries: int = 0             # perturbed re-factor retries consumed
    latency_s: float | None = None  # submit→result latency (async server)
    deadline_missed: bool = False   # completed after its deadline (async)

    @property
    def ok(self) -> bool:
        """True iff this request solved at tolerance (``status=="solved"``
        and refinement converged)."""
        return self.status == STATUS_SOLVED and not self.refine_failed


def _residual_key(r: SolveResult) -> float:
    """Max residual as a comparison key; NaN/Inf ranks worst, so a retry
    with any finite residual beats a NaN original."""
    v = float(np.max(r.residual))
    return v if np.isfinite(v) else float("inf")


def _as_csr(a) -> CSR:
    if isinstance(a, CSR):
        return a
    if hasattr(a, "tocsr"):
        return CSR.from_scipy(a.tocsr())
    raise TypeError(f"request matrix must be a CSR (or scipy sparse), got "
                    f"{type(a).__name__}")


def validate_request(a, b):
    """Admission-time validation of one request: returns
    ``(a_csr, b_arr, None)`` for an admissible request or
    ``(None, None, SolveError)`` with a typed taxonomy error.

    Checks, in order: the matrix converts to :class:`CSR`; values and RHS
    are real numeric dtypes; the RHS is ``(n,)`` or ``(n, m)``; values and
    RHS are finite (NaN/Inf never reach a jitted batch, where they would
    come back as silent garbage); the pattern is structurally nonsingular
    (no empty row or column — such a system cannot be factored at all)."""
    try:
        a = _as_csr(a)
    except TypeError as e:
        return None, None, SolveError(ERR_BAD_MATRIX, str(e))
    vals = np.asarray(a.data)
    if not (np.issubdtype(vals.dtype, np.floating)
            or np.issubdtype(vals.dtype, np.integer)):
        return None, None, SolveError(
            ERR_BAD_DTYPE, f"matrix values must be real numeric, got dtype "
            f"{vals.dtype}", dict(dtype=str(vals.dtype), field="a"))
    b = np.asarray(b)
    if not (np.issubdtype(b.dtype, np.floating)
            or np.issubdtype(b.dtype, np.integer)):
        return None, None, SolveError(
            ERR_BAD_DTYPE, f"RHS must be real numeric, got dtype {b.dtype}",
            dict(dtype=str(b.dtype), field="b"))
    if b.ndim not in (1, 2) or b.shape[0] != a.n:
        return None, None, SolveError(
            ERR_SHAPE_MISMATCH,
            f"request RHS shape {b.shape} does not match its matrix "
            f"(n={a.n}; expected (n,) or (n, m))",
            dict(rhs_shape=tuple(b.shape), n=a.n))
    finite = np.isfinite(vals)
    if not finite.all():
        bad = int(np.argmin(finite))
        return None, None, SolveError(
            ERR_NONFINITE_VALUES,
            f"matrix values contain {int((~finite).sum())} non-finite "
            f"entries (first at nnz index {bad})",
            dict(n_nonfinite=int((~finite).sum()), first_index=bad))
    finite_b = np.isfinite(b)
    if not finite_b.all():
        bad = int(np.argmin(finite_b.ravel()))
        return None, None, SolveError(
            ERR_NONFINITE_RHS,
            f"RHS contains {int((~finite_b).sum())} non-finite entries "
            f"(first at flat index {bad})",
            dict(n_nonfinite=int((~finite_b).sum()), first_index=bad))
    counts = np.diff(a.indptr)
    if (counts == 0).any():
        row = int(np.argmin(counts > 0))
        return None, None, SolveError(
            ERR_SINGULAR_PATTERN,
            f"structurally singular: row {row} has no entries",
            dict(kind="empty_row", index=row))
    col_hits = np.bincount(np.asarray(a.indices, dtype=np.int64),
                           minlength=a.n)
    if (col_hits == 0).any():
        col = int(np.argmin(col_hits > 0))
        return None, None, SolveError(
            ERR_SINGULAR_PATTERN,
            f"structurally singular: column {col} has no entries",
            dict(kind="empty_column", index=col))
    return a, b, None


class SolverService:
    """Front-end for heterogeneous (pattern, values, b) solve traffic.

    opts           — HyluOptions template applied to every request (mesh,
                     refinement, kernel thresholds, retry ladder, …)
    cache          — a PlanCache to share across services; built from
                     cache_dir/cache_capacity when None
    cache_dir      — artifact-store directory for the internally-built
                     cache (None disables disk persistence; the default
                     sentinel resolves under ``opts.cache_root`` /
                     ``$HYLU_CACHE_ROOT`` / the repo's ``checkpoints``
                     dir — see ``repro.core.plan_cache.resolve_cache_dir``)
    cache_capacity — LRU bound of the internally-built cache
    batch_size     — fixed dispatch batch: every group is chunked and
                     padded up to this many systems, so each pattern
                     compiles ONE batched program regardless of how the
                     traffic mix fluctuates; None dispatches each group at
                     its natural size (one compile per distinct group size)

    Use ``solve_batch(requests)`` for one-shot dispatch, or
    ``submit(a, b)`` + ``flush()`` to accumulate a serving window first.
    ``solve_batch`` never raises for a per-request problem — it returns a
    typed terminal result per request (see the module docstring's fault-
    tolerance contract); ``submit`` raises :class:`InvalidRequestError`
    eagerly so the queued window only ever holds admissible requests.
    """

    def __init__(self, opts: HyluOptions | None = None,
                 cache: PlanCache | None = None,
                 cache_dir: str | None = DEFAULT_CACHE_DIR,
                 cache_capacity: int = 32,
                 batch_size: int | None = 8):
        self.opts = opts or HyluOptions()
        self.cache = cache if cache is not None else PlanCache(
            capacity=cache_capacity, directory=cache_dir,
            cache_root=self.opts.cache_root)
        if batch_size is not None and batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.batch_size = batch_size
        self.stats = dict(requests=0, groups=0, dispatches=0,
                          padded_systems=0, patterns_seen=0, solve_s=0.0,
                          refine_failed=0, fp64_fallbacks=0,
                          rejected=0, failed=0, quarantined=0, retries=0)
        self._pattern_modes: dict[str, str] = {}   # fingerprint → kernel mode
        self._pending: list[SolveRequest] = []

    # ---------------------------------------------------------------- queue
    def submit(self, a, b, tag=None, factor_dtype=None) -> int:
        """Validate and enqueue one request; returns its position in the
        next flush.  A request that fails admission validation raises
        :class:`InvalidRequestError` (with the typed ``SolveError`` as
        ``.error``) *here*, before it can enter the window — the queue
        only ever holds admissible requests."""
        a, b, err = validate_request(a, b)
        if err is not None:
            raise InvalidRequestError(err)
        self._pending.append(SolveRequest(a=a, b=b, tag=tag,
                                          factor_dtype=factor_dtype))
        return len(self._pending) - 1

    def flush(self) -> list:
        """Dispatch every queued request; results in submit order.  Every
        request receives a terminal result (``solve_batch`` isolates
        per-group failures instead of raising), so the window is always
        cleared — nothing is ever silently dropped."""
        results = self.solve_batch(self._pending)
        self._pending = []
        return results

    # ------------------------------------------------------------- dispatch
    def solve_batch(self, requests) -> list:
        """Group a heterogeneous request list by plan fingerprint, dispatch
        each group through the cached batched engines, and scatter results
        back to request order.  Requests may be ``SolveRequest`` objects or
        bare ``(a, b)`` pairs.  Returns ``list[SolveResult]`` aligned with
        ``requests`` — one terminal result per request (rejected / failed /
        quarantined results carry a typed ``error``; this method does not
        raise for per-request problems)."""
        t0 = time.perf_counter()
        reqs: list = []
        results: list = [None] * len(requests)
        for i, r in enumerate(requests):
            if not isinstance(r, SolveRequest):
                a, b = r
                r = SolveRequest(a=a, b=b)
            a, b, err = validate_request(r.a, r.b)
            if err is not None:
                self.stats["rejected"] += 1
                results[i] = SolveResult(status=STATUS_REJECTED, error=err,
                                         tag=r.tag)
                reqs.append(None)
                continue
            reqs.append(SolveRequest(a=a, b=b, tag=r.tag,
                                     factor_dtype=r.factor_dtype))

        valid = [i for i, r in enumerate(reqs) if r is not None]
        self._group_and_dispatch(reqs, valid, results)
        self._escalate(reqs, results)

        self.stats["requests"] += len(reqs)
        self.stats["refine_failed"] += sum(
            1 for r in results if r is not None and r.refine_failed)
        self.stats["solve_s"] += time.perf_counter() - t0
        return results

    def _opts_for(self, req: SolveRequest, retry_attempt: int = 0):
        """The effective HyluOptions for one request: the service template,
        a per-request factor_dtype override, and — for escalation-ladder
        retries — the boosted pivot-perturbation threshold (an explicit
        perturb_eps ⇒ a distinct plan fingerprint)."""
        opts = (self.opts if req.factor_dtype is None else
                dataclasses.replace(self.opts,
                                    factor_dtype=req.factor_dtype))
        if retry_attempt > 0:
            opts = dataclasses.replace(
                opts, perturb_eps=resolve_retry_perturb(opts, retry_attempt))
        return opts

    def _group_and_dispatch(self, reqs, idx_list, results,
                            retry_attempt: int = 0):
        """Group the given request indices by (fingerprint, RHS tail shape),
        preserving request order within each group, and dispatch each group
        through the cached batched engines under per-group error isolation.
        Differing multi-RHS widths of one pattern dispatch separately (the
        batched RHS must be rectangular); factor_dtype is a
        PLAN_OPTION_FIELDS member, so a per-request dtype override lands in
        a different fingerprint — mixed-precision traffic routes into
        separate groups with no extra machinery."""
        groups: dict[tuple, list[int]] = {}
        group_opts: dict[str, HyluOptions] = {}
        for i in idx_list:
            r = reqs[i]
            opts_i = self._opts_for(r, retry_attempt)
            fp = plan_fingerprint(r.a, opts_i)
            group_opts[fp] = opts_i
            groups.setdefault((fp, r.b.shape[1:]), []).append(i)

        for (fp, _tail), idxs in groups.items():
            new_pattern = fp not in self._pattern_modes
            try:
                an = self.cache.get_or_analyze(reqs[idxs[0]].a,
                                               group_opts[fp],
                                               fingerprint=fp)
            except Exception as e:      # noqa: BLE001 — isolation barrier
                self._fail_group(reqs, idxs, results, fp, "analyze", e)
                continue
            if new_pattern:
                self.stats["patterns_seen"] += 1
            self.stats["groups"] += 1
            self._pattern_modes[fp] = an.choice.mode
            step = self.batch_size or len(idxs)
            for c0 in range(0, len(idxs), step):
                chunk = idxs[c0:c0 + step]
                try:
                    self._dispatch(an, fp, reqs, chunk, pad_to=step,
                                   group_size=len(idxs), results=results)
                except Exception as e:  # noqa: BLE001 — isolation barrier
                    self._fail_group(reqs, chunk, results, fp, "dispatch", e)

    def _fail_group(self, reqs, idxs, results, fp, stage, exc):
        """One pattern group (or chunk) raised: every affected request gets
        a typed ``failed`` result; every other group is untouched."""
        err_type = type(exc).__name__
        for i in idxs:
            self.stats["failed"] += 1
            results[i] = SolveResult(
                status=STATUS_FAILED, tag=reqs[i].tag, fingerprint=fp,
                error=SolveError(
                    ERR_DISPATCH,
                    f"{stage} raised {err_type}: {exc}",
                    dict(stage=stage, exception=err_type,
                         fingerprint=fp, group_size=len(idxs))))

    def _escalate(self, reqs, results):
        """The escalation ladder's serving half.  Stage 1 (refinement) and
        stage 2 (the batched fp64 fallback) already ran inside
        ``solve_batched``; what reaches here still carrying
        ``refine_failed`` gets stage 3 — up to ``opts.retry_max``
        re-dispatches with a boosted pivot-perturbation threshold — and
        what survives all of that becomes stage 4: a quarantined result
        with diagnostics."""
        retry_max = max(0, int(self.opts.retry_max))
        for attempt in range(1, retry_max + 1):
            todo = [i for i, r in enumerate(results)
                    if r is not None and r.status == STATUS_SOLVED
                    and r.refine_failed]
            if not todo:
                break
            retry_results: list = [None] * len(reqs)
            self._group_and_dispatch(reqs, todo, retry_results,
                                     retry_attempt=attempt)
            for i in todo:
                self.stats["retries"] += 1
                results[i].n_retries = attempt
                rr = retry_results[i]
                if rr is None or rr.status != STATUS_SOLVED:
                    continue            # retry dispatch itself failed: keep
                    #                     the original attempt's answer
                rr.n_retries = attempt
                if not rr.refine_failed or (
                        _residual_key(rr) < _residual_key(results[i])):
                    results[i] = rr
        for r in results:
            if r is not None and r.status == STATUS_SOLVED and r.refine_failed:
                self.stats["quarantined"] += 1
                r.status = STATUS_QUARANTINED
                r.error = SolveError(
                    ERR_QUARANTINED,
                    "refinement never reached tolerance (after the fp64 "
                    f"fallback and {r.n_retries} perturbed re-factor "
                    "retries) — solution quarantined",
                    dict(residual=float(np.max(r.residual)),
                         n_refine=r.n_refine, n_perturb=r.n_perturb,
                         n_retries=r.n_retries,
                         factor_dtype=r.factor_dtype))

    def _dispatch(self, an, fp, reqs, chunk, pad_to, group_size, results):
        """One padded batched factor+solve for ``chunk`` (request indices
        of one pattern/RHS-shape group), scattered into ``results``."""
        import jax

        g = len(chunk)
        k = max(pad_to, g)
        a0 = reqs[chunk[0]].a
        # stage in the engine's staging (= refine) dtype: fp64 for pure-fp64
        # and mixed reduced-factor engines, the factor dtype for a pure
        # reduced-precision engine — one cast, no fp64 detour
        _, rname = resolve_dtype_names(an.opts, jax.config.jax_enable_x64)
        sdt = np_dtype(rname)
        vb = np.empty((k, a0.nnz), dtype=sdt)
        bb = np.zeros((k,) + reqs[chunk[0]].b.shape, dtype=sdt)
        for j, i in enumerate(chunk):
            vb[j] = reqs[i].a.data
            bb[j] = reqs[i].b
        # pad with the chunk's first system + zero RHS: well-conditioned,
        # converges on iteration 0 under the per-system alive-masking
        vb[g:] = vb[0]

        bst = factor_batched(an, (a0.indptr, a0.indices), vb)
        x, info = solve_batched(bst, bb)
        self.stats["dispatches"] += 1
        self.stats["padded_systems"] += k - g
        self.stats["fp64_fallbacks"] += int(info.get("n_fp64_fallback", 0))
        failed = np.asarray(info["refine_failed"])
        for j, i in enumerate(chunk):
            req_failed = bool(np.any(failed[j]))
            results[i] = SolveResult(
                x=x[j],
                residual=(float(info["residual"][j])
                          if np.ndim(info["residual"][j]) == 0
                          else np.asarray(info["residual"][j])),
                n_refine=int(info["n_refine_per_system"][j].max()
                             if np.ndim(info["n_refine_per_system"][j])
                             else info["n_refine_per_system"][j]),
                n_perturb=int(info["n_perturb"][j]),
                fingerprint=fp, group_size=group_size, tag=reqs[i].tag,
                refine_failed=req_failed,
                factor_dtype=info["factor_dtype"])

    # ------------------------------------------------------------ introspect
    @property
    def pattern_modes(self) -> dict:
        """fingerprint → kernel mode chosen for that pattern (rowrow /
        hybrid / supernodal) — the routing record tests assert on."""
        return dict(self._pattern_modes)
