"""Shard-aware checkpointing with async save, atomic commit, and elastic
restore (resume onto a different mesh/topology).

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json     — tree structure, shapes, dtypes, spec strings
        arrays/<idx>.npy  — one file per leaf (full array; per-host sharded
                            writes would split along the first sharded dim —
                            on this single-host container every leaf is
                            written by host 0, which is also the multi-pod
                            restore story: any host count can re-read)
        COMMIT            — written last; restore ignores uncommitted dirs

Fault-tolerance contract used by the Trainer:
  - save is atomic (tmp dir + rename + COMMIT marker): a crash mid-save
    never corrupts the latest checkpoint;
  - restore picks the newest committed step ≤ requested;
  - elastic: arrays are stored unsharded + respec'd on load, so restoring
    onto a different mesh (grow/shrink) just re-applies the new sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import numpy as np

import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [l for _, l in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False):
        """Device→host transfer happens synchronously (values are snapshot-
        consistent); file IO happens on a background thread."""
        self.wait()
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(l) for l in leaves]

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step:09d}")
            final = os.path.join(self.dir, f"step_{step:09d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(os.path.join(tmp, "arrays"))
            manifest = dict(step=step, leaves=[])
            for i, (p, a) in enumerate(zip(paths, host_leaves)):
                np.save(os.path.join(tmp, "arrays", f"{i}.npy"), a)
                manifest["leaves"].append(
                    dict(path=p, shape=list(a.shape), dtype=str(a.dtype)))
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(final, "COMMIT"), "w") as f:
                f.write("ok")
            self._gc()

        if self.async_save and not blocking:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def committed_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
        return out

    def latest_step(self):
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of ``like_tree``; optionally re-shard
        with ``shardings`` (elastic resume on a new mesh)."""
        final = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(final, "manifest.json")) as f:
            manifest = json.load(f)
        paths, leaves, treedef = _flatten_with_paths(like_tree)
        by_path = {l["path"]: i for i, l in enumerate(manifest["leaves"])}
        arrays = []
        for p, ref in zip(paths, leaves):
            idx = by_path[p]
            a = np.load(os.path.join(final, "arrays", f"{idx}.npy"))
            assert list(a.shape) == list(ref.shape), (p, a.shape, ref.shape)
            arrays.append(a)
        tree = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.device_put, tree)
        return tree
