"""Pure-jnp oracle for the RWKV6 WKV recurrence (per-head, time scan):

    y_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
"""
import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, s0=None):
    """r/k/v/w: (BH, T, hs); u: (hs,) or (BH, hs). Returns (y, s_final)."""
    bh, t, hs = r.shape
    if s0 is None:
        s0 = jnp.zeros((bh, hs, hs), jnp.float32)
    uu = u if u.ndim == 2 else jnp.broadcast_to(u[None], (bh, hs))

    def step(s, inp):
        rt, kt, vt, wt = inp
        kv = kt[:, :, None] * vt[:, None, :]
        y = jnp.einsum("bk,bkv->bv", rt, s + uu[:, :, None] * kv)
        s = wt[:, :, None] * s + kv
        return s, y

    s, ys = jax.lax.scan(
        step, s0, (jnp.moveaxis(r, 1, 0).astype(jnp.float32),
                   jnp.moveaxis(k, 1, 0).astype(jnp.float32),
                   jnp.moveaxis(v, 1, 0).astype(jnp.float32),
                   jnp.moveaxis(w, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(ys, 0, 1), s
