"""jit'd wrapper for the WKV Pallas kernel."""
import jax
import jax.numpy as jnp

from .kernel import wkv
from .ref import wkv_ref

__all__ = ["wkv", "wkv_ref", "wkv_padded"]


def wkv_padded(r, k, v, w, u, bt: int = 256, interpret: bool = True):
    """Pads T to a tile multiple (decay w pads with 1.0 = identity)."""
    bh, t, hs = r.shape
    bt = min(bt, t)
    tp = -(-t // bt) * bt
    if tp != t:
        pad = ((0, 0), (0, tp - t), (0, 0))
        r = jnp.pad(r, pad)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)
    return wkv(r, k, v, w, u, bt=bt, interpret=interpret)[:, :t]
