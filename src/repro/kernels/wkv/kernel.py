"""Pallas TPU kernel: RWKV6 WKV recurrence with VMEM-resident state.

The pure-XLA time scan round-trips the (hs×hs) matrix state through HBM
every step: measured 2.06e15 bytes/device on rwkv6-1.6b × train_4k — a
2514 s memory term, the single worst roofline cell in the sweep. This
kernel keeps the state in a VMEM scratch across a T-tiled grid: HBM sees
only the r/k/v/w streams and the y output.

Grid: (BH, T/BT) with T innermost ("arbitrary"): the state scratch carries
across time tiles of the same (batch·head); inside a tile the recurrence
runs as a fori over BT steps on VMEM values (the per-step work is an
hs×hs outer product + matvec — VPU-friendly at hs=64).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
                bt: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[...]                       # (1, hs)

    def step(i, s):
        rt = r_ref[0, i, :]
        kt = k_ref[0, i, :]
        vt = v_ref[0, i, :]
        wt = w_ref[0, i, :]
        kv = kt[:, None] * vt[None, :]
        y = rt @ (s + u[0][:, None] * kv)
        y_ref[0, i, :] = y.astype(y_ref.dtype)
        return wt[:, None] * s + kv

    s_scr[...] = jax.lax.fori_loop(0, bt, step, s_scr[...])


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def wkv(r, k, v, w, u, bt: int = 256, interpret: bool = True):
    """r/k/v/w: (BH, T, hs) — w is the per-step decay (already exp'd);
    u: (BH, hs) bonus. Returns y: (BH, T, hs)."""
    bh, t, hs = r.shape
    bt = min(bt, t)
    assert t % bt == 0, "pad T to a multiple of the time tile"
    grid = (bh, t // bt)
    return pl.pallas_call(
        functools.partial(_wkv_kernel, bt=bt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, hs), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, hs), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, hs), jnp.float32),
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
      w.astype(jnp.float32), u.astype(jnp.float32))
