"""jit'd wrapper for the flash-attention Pallas kernel."""
import jax

from .kernel import flash_attention
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]
