"""Pure-jnp oracle for causal (optionally GQA) attention."""
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True, scale: float | None = None):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D); Hq % Hkv == 0."""
    b, hq, t, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    if causal:
        s = k.shape[2]
        mask = jnp.arange(t)[:, None] + (s - t) >= jnp.arange(s)[None, :]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", w, v)
