"""Pallas TPU kernel: FlashAttention-style causal attention (fwd).

Online-softmax over KV tiles with running (m, l, acc) VMEM scratch carried
across the innermost ("arbitrary") grid dimension.  GQA is handled by the
index map (query-head h reads kv-head h // group).

Grid: (batch*heads, T/BQ, S/BK); the kv axis must be innermost so the
scratch accumulators persist per (bh, q-tile).

VMEM budget per step: BQ×D (q) + 2×BK×D (k,v) + BQ×BK (logits) + BQ×D (acc)
≈ 4 tiles of 128×128 fp32 ≈ 256 KiB — comfortably inside 16 MiB VMEM, the
rest of the budget is pipeline double-buffering.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.5 renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, scale: float, causal: bool, n_k: int,
                  s_valid: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # with causal masking, tiles strictly above the diagonal are skipped
    run = (not causal) or (qi * bq + bq - 1 >= ki * bk)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                   # (BQ, D)
        k = k_ref[0]                                   # (BK, D)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < s_valid                      # padded kv columns
        if causal:
            mask = mask & (rows >= cols)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)[:, None]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=1)[:, None]
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _done():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)       # fully-masked (padded) rows
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def flash_attention(q, k, v, bq: int = 128, bk: int = 128,
                    causal: bool = True, interpret: bool = True):
    """q: (B, Hq, T, D); k/v: (B, Hkv, S, D). Returns (B, Hq, T, D)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    scale = 1.0 / (d ** 0.5)
    bq = min(bq, t)
    bk = min(bk, s)
    t0, s0 = t, s
    # pad sequence dims to tile multiples: OOB tile reads are undefined
    # (NaN-filled in interpret mode) and 0·NaN would poison the GEMM.
    tp = -(-t // bq) * bq
    sp = -(-s // bk) * bk
    if tp != t:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tp - t), (0, 0)))
        t = tp
    if sp != s:
        pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        s = sp
    qr = q.reshape(b * hq, t, d)
    kr = k.reshape(b * hkv, s, d)
    vr = v.reshape(b * hkv, s, d)
    grid = (b * hq, pl.cdiv(t, bq), pl.cdiv(s, bk))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale,
                          causal=causal, n_k=grid[2], s_valid=s0),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, t, d)[:, :, :t0, :]
