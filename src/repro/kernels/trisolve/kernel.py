"""Pallas TPU kernel: dense row-panel triangular solve  Y @ U = X.

This is the in-VMEM TRSM used by the sup-row / sup-sup numeric kernels
(the "solve against the source supernode's diagonal block" step).  The
whole problem fits one VMEM block by construction: supernode widths are
capped at analysis time (max_super ≤ 128, MXU-aligned), and panel heights
are tiled by the caller.

Tiling: grid over row tiles of X (TILE_NR rows each); U (k×k, k ≤ 128)
is resident in VMEM for every tile.  Inside the kernel the solve runs as
k sequential column updates on the VPU/MXU (the recurrence is inherently
sequential in k, parallel over rows — exactly the paper's sup-row shape).
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trsm_kernel(u_ref, x_ref, y_ref, *, k: int, unit_diag: bool):
    x = x_ref[...]
    u = u_ref[...]

    def body(j, y):
        acc = x[:, j] - y @ u[:, j]
        if not unit_diag:
            acc = acc / u[j, j]
        return y.at[:, j].set(acc)

    y = jax.lax.fori_loop(0, k, body, jnp.zeros_like(x))
    y_ref[...] = y


@functools.partial(jax.jit, static_argnames=("tile_nr", "interpret",
                                             "unit_diag"))
def trsm_upper(u: jax.Array, x: jax.Array, tile_nr: int = 256,
               interpret: bool = True, unit_diag: bool = False) -> jax.Array:
    """Solve Y @ U = X. u: (k, k) upper-tri; x: (nr, k).

    ``unit_diag=True`` treats U's diagonal as implicit ones (skips the
    per-column divide) — the shape of the unit-lower left-solve that the
    engine's block substitution routes through this kernel transposed."""
    nr, k = x.shape
    tile = min(tile_nr, max(nr, 1))
    grid = (pl.cdiv(nr, tile),)
    return pl.pallas_call(
        functools.partial(_trsm_kernel, k=k, unit_diag=unit_diag),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, k), lambda i: (0, 0)),        # U resident
            pl.BlockSpec((tile, k), lambda i: (i, 0)),     # row tile of X
        ],
        out_specs=pl.BlockSpec((tile, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr, k), x.dtype),
        interpret=interpret,
    )(u, x)
