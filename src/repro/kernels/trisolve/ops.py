"""jit'd wrappers for the TRSM Pallas kernel (padding to TPU-friendly tiles).

Besides the raw right-solve (Y @ U = X) this exposes the two *left*-solve
shapes the engine's block substitution needs — U·w = b and (unit) L·w = b,
batched over K systems — expressed on the same Pallas kernel through the
transpose/flip identities

    L w = b           ⇔  wᵀ = bᵀ · (Lᵀ)⁻¹          (Lᵀ upper, unit diag)
    U w = b           ⇔  (Jw)ᵀ = (Jb)ᵀ · ((JUJ)ᵀ)⁻¹  (J U ᵀ J upper)

where J is the row-flip. These are what ``jax_engine`` routes the bulk
supernode diagonal blocks through when ``use_pallas=True`` (interpret mode
on CPU; compiled on real TPUs).

Dtype contract: every op runs in its operands' dtype (float64 / float32 /
bfloat16) — tile padding builds identity diagonals in ``u.dtype`` and the
solves never upcast, so the mixed-precision engine's reduced-precision
substitution path flows through unchanged.
"""
import jax
import jax.numpy as jnp

from .kernel import trsm_upper
from .ref import (trsm_upper_ref, trsm_upper_ref_batched,
                  trsm_left_upper_ref_batched,
                  trsm_left_unit_lower_ref_batched)

__all__ = ["trsm", "trsm_batched", "trsm_left_upper_batched",
           "trsm_left_unit_lower_batched", "trsm_upper_ref",
           "trsm_upper_ref_batched", "trsm_left_upper_ref_batched",
           "trsm_left_unit_lower_ref_batched"]


def trsm(u: jax.Array, x: jax.Array, interpret: bool = True,
         unit_diag: bool = False) -> jax.Array:
    """Solve Y @ U = X with the Pallas kernel. Pads k to a multiple of 8
    (sublane) — padded diagonal is identity so the solve is unaffected."""
    nr, k = x.shape
    kp = max(8, -(-k // 8) * 8)
    if kp != k:
        u_p = jnp.eye(kp, dtype=u.dtype).at[:k, :k].set(u)
        x_p = jnp.zeros((nr, kp), x.dtype).at[:, :k].set(x)
        return trsm_upper(u_p, x_p, interpret=interpret,
                          unit_diag=unit_diag)[:, :k]
    return trsm_upper(u, x, interpret=interpret, unit_diag=unit_diag)


def trsm_batched(u: jax.Array, x: jax.Array, interpret: bool = True,
                 unit_diag: bool = False) -> jax.Array:
    """Batched TRSM: u (K, k, k), x (K, nr, k) — K independent panel solves
    through one vmapped pallas_call.  This is the op behind the engine's
    ``use_pallas`` block-substitution path (via the left-solve wrappers
    below) and the supernode panel updates."""
    nr, k = x.shape[-2:]
    kp = max(8, -(-k // 8) * 8)
    if kp != k:
        kb = x.shape[0]
        u_p = (jnp.zeros((kb, kp, kp), u.dtype)
               .at[:, jnp.arange(kp), jnp.arange(kp)].set(1.0)
               .at[:, :k, :k].set(u))
        x_p = jnp.zeros((kb, nr, kp), x.dtype).at[:, :, :k].set(x)
        y = jax.vmap(lambda uu, xx: trsm_upper(uu, xx, interpret=interpret,
                                               unit_diag=unit_diag))(u_p, x_p)
        return y[:, :, :k]
    return jax.vmap(lambda uu, xx: trsm_upper(uu, xx, interpret=interpret,
                                              unit_diag=unit_diag))(u, x)


def trsm_left_unit_lower_batched(blk: jax.Array, b: jax.Array,
                                 interpret: bool = True) -> jax.Array:
    """Solve L[i] @ w[i] = b[i] with L = tril(blk[i], -1) + I.

    blk (K, k, k) dense diagonal blocks straight from the panel buffer
    (upper part, which holds U values, is ignored); b (K, k, m)."""
    lt = jnp.triu(jnp.swapaxes(blk, 1, 2), 1)          # Lᵀ, strict upper
    y = trsm_batched(lt, jnp.swapaxes(b, 1, 2), interpret=interpret,
                     unit_diag=True)                    # (K, m, k) = wᵀ
    return jnp.swapaxes(y, 1, 2)


def trsm_left_upper_batched(blk: jax.Array, b: jax.Array,
                            interpret: bool = True) -> jax.Array:
    """Solve U[i] @ w[i] = b[i] with U = triu(blk[i]).

    blk (K, k, k) dense diagonal blocks straight from the panel buffer
    (strict lower part, which holds L values, is ignored); b (K, k, m)."""
    u_flip = jnp.flip(jnp.swapaxes(jnp.triu(blk), 1, 2), axis=(1, 2))
    y = trsm_batched(u_flip, jnp.swapaxes(jnp.flip(b, axis=1), 1, 2),
                     interpret=interpret)               # (K, m, k) = (Jw)ᵀ
    return jnp.flip(jnp.swapaxes(y, 1, 2), axis=1)
