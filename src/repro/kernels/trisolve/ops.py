"""jit'd wrapper for the TRSM Pallas kernel (padding to TPU-friendly tiles)."""
import jax
import jax.numpy as jnp

from .kernel import trsm_upper
from .ref import trsm_upper_ref, trsm_upper_ref_batched

__all__ = ["trsm", "trsm_batched", "trsm_upper_ref", "trsm_upper_ref_batched"]


def trsm(u: jax.Array, x: jax.Array, interpret: bool = True) -> jax.Array:
    """Solve Y @ U = X with the Pallas kernel. Pads k to a multiple of 8
    (sublane) — padded diagonal is identity so the solve is unaffected."""
    nr, k = x.shape
    kp = max(8, -(-k // 8) * 8)
    if kp != k:
        u_p = jnp.eye(kp, dtype=u.dtype).at[:k, :k].set(u)
        x_p = jnp.zeros((nr, kp), x.dtype).at[:, :k].set(x)
        return trsm_upper(u_p, x_p, interpret=interpret)[:, :k]
    return trsm_upper(u, x, interpret=interpret)


def trsm_batched(u: jax.Array, x: jax.Array, interpret: bool = True) -> jax.Array:
    """Batched TRSM: u (K, k, k), x (K, nr, k) — K independent panel solves
    through one vmapped pallas_call.

    Standalone building block for a future Pallas-batched factorization
    path; the current batched engine (`jax_engine.RepeatedSolveEngine`)
    vmaps the whole factor program and uses the segment-sum batched
    tri-solve for substitution, so this op is not yet on that path."""
    nr, k = x.shape[-2:]
    kp = max(8, -(-k // 8) * 8)
    if kp != k:
        kb = x.shape[0]
        u_p = (jnp.zeros((kb, kp, kp), u.dtype)
               .at[:, jnp.arange(kp), jnp.arange(kp)].set(1.0)
               .at[:, :k, :k].set(u))
        x_p = jnp.zeros((kb, nr, kp), x.dtype).at[:, :, :k].set(x)
        y = jax.vmap(lambda uu, xx: trsm_upper(uu, xx, interpret=interpret))(
            u_p, x_p)
        return y[:, :, :k]
    return jax.vmap(lambda uu, xx: trsm_upper(uu, xx, interpret=interpret))(u, x)
