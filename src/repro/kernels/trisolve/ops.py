"""jit'd wrapper for the TRSM Pallas kernel (padding to TPU-friendly tiles)."""
import jax
import jax.numpy as jnp

from .kernel import trsm_upper
from .ref import trsm_upper_ref

__all__ = ["trsm", "trsm_upper_ref"]


def trsm(u: jax.Array, x: jax.Array, interpret: bool = True) -> jax.Array:
    """Solve Y @ U = X with the Pallas kernel. Pads k to a multiple of 8
    (sublane) — padded diagonal is identity so the solve is unaffected."""
    nr, k = x.shape
    kp = max(8, -(-k // 8) * 8)
    if kp != k:
        u_p = jnp.eye(kp, dtype=u.dtype).at[:k, :k].set(u)
        x_p = jnp.zeros((nr, kp), x.dtype).at[:, :k].set(x)
        return trsm_upper(u_p, x_p, interpret=interpret)[:, :k]
    return trsm_upper(u, x, interpret=interpret)
