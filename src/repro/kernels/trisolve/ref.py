"""Pure-jnp oracle for the TRSM kernel: solve Y @ U = X, U upper-triangular
(non-unit diagonal), vectorized over rows of X."""
import jax.numpy as jnp
import jax


def trsm_upper_ref(u: jax.Array, x: jax.Array) -> jax.Array:
    """u: (k, k) upper-triangular; x: (nr, k). Returns y with y @ u == x."""
    k = u.shape[0]

    def body(j, y):
        acc = x[:, j] - y @ u[:, j]          # y[:, >=j] are still 0
        return y.at[:, j].set(acc / u[j, j])

    y0 = jnp.zeros_like(x)
    return jax.lax.fori_loop(0, k, body, y0)


def trsm_upper_ref_batched(u: jax.Array, x: jax.Array) -> jax.Array:
    """Batched oracle: u (K, k, k), x (K, nr, k); y[i] @ u[i] == x[i]."""
    k = u.shape[-1]

    def body(j, y):
        acc = x[..., j] - jnp.einsum("bnk,bk->bn", y, u[..., j])
        return y.at[..., j].set(acc / u[:, j, j][:, None])

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(x))
