"""Pure-jnp oracle for the TRSM kernel: solve Y @ U = X, U upper-triangular
(non-unit diagonal), vectorized over rows of X."""
import jax.numpy as jnp
import jax


def trsm_upper_ref(u: jax.Array, x: jax.Array) -> jax.Array:
    """u: (k, k) upper-triangular; x: (nr, k). Returns y with y @ u == x."""
    k = u.shape[0]

    def body(j, y):
        acc = x[:, j] - y @ u[:, j]          # y[:, >=j] are still 0
        return y.at[:, j].set(acc / u[j, j])

    y0 = jnp.zeros_like(x)
    return jax.lax.fori_loop(0, k, body, y0)


def trsm_upper_ref_batched(u: jax.Array, x: jax.Array) -> jax.Array:
    """Batched oracle: u (K, k, k), x (K, nr, k); y[i] @ u[i] == x[i]."""
    k = u.shape[-1]

    def body(j, y):
        acc = x[..., j] - jnp.einsum("bnk,bk->bn", y, u[..., j])
        return y.at[..., j].set(acc / u[:, j, j][:, None])

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(x))


def trsm_left_upper_ref_batched(blk: jax.Array, b: jax.Array) -> jax.Array:
    """Left-solve oracle: U[i] @ w[i] = b[i] with U = triu(blk[i]).
    blk (K, k, k) dense (strict lower ignored); b (K, k, m)."""
    u = jnp.triu(blk)

    def body(jj, w):
        j = blk.shape[-1] - 1 - jj
        acc = b[:, j] - jnp.einsum("bk,bkm->bm", u[:, j], w)
        return w.at[:, j].set(acc / u[:, j, j][:, None])

    return jax.lax.fori_loop(0, blk.shape[-1], body, jnp.zeros_like(b))


def trsm_left_unit_lower_ref_batched(blk: jax.Array, b: jax.Array) -> jax.Array:
    """Left-solve oracle: L[i] @ w[i] = b[i] with L = tril(blk[i], -1) + I.
    blk (K, k, k) dense (upper incl. diag ignored); b (K, k, m)."""
    l = jnp.tril(blk, -1)

    def body(j, w):
        acc = b[:, j] - jnp.einsum("bk,bkm->bm", l[:, j], w)
        return w.at[:, j].set(acc)

    return jax.lax.fori_loop(0, blk.shape[-1], body, jnp.zeros_like(b))
