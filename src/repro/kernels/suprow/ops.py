"""jit'd wrapper for the fused sup-row Pallas kernel."""
import jax
import jax.numpy as jnp

from .kernel import suprow_update_p
from .ref import suprow_update_ref

__all__ = ["suprow_update", "suprow_update_ref"]


def suprow_update(x: jax.Array, src: jax.Array, k: int,
                  interpret: bool = True):
    """x: (k+m,) target row slice; src: (k, k+m). Returns (y, xr)."""
    m = x.shape[0] - k

    def rnd(v, mult=8):
        return max(mult, -(-v // mult) * mult)

    kp, mp = rnd(k), rnd(max(m, 1), 128 if m >= 128 else 8)
    u = jnp.eye(kp, dtype=x.dtype).at[:k, :k].set(src[:, :k])
    b = jnp.zeros((kp, mp), x.dtype).at[:k, :m].set(src[:, k:])
    xk = jnp.zeros((1, kp), x.dtype).at[0, :k].set(x[:k])
    xm = jnp.zeros((1, mp), x.dtype).at[0, :m].set(x[k:])
    y, xr = suprow_update_p(xk, xm, u, b, interpret=interpret)
    return y[0, :k], xr[0, :m]
