"""Pure-jnp oracle for the sup-row kernel (level-2 BLAS shape):
a source supernode updates a single target row.

    y   = x[:k] @ inv(U_SS)        (TRSV against the diag block)
    xr  = x[k:] - y @ B            (GEMV against the U panel)
"""
import jax
import jax.numpy as jnp


def suprow_update_ref(x: jax.Array, src: jax.Array, k: int):
    """x: (k+m,) target row slice; src: (k, k+m) source rows."""
    u = src[:, :k]

    def body(j, y):
        acc = x[j] - y @ u[:, j]
        return y.at[j].set(acc / u[j, j])

    y = jax.lax.fori_loop(0, k, body, jnp.zeros((k,), x.dtype))
    xr = x[k:] - y @ src[:, k:]
    return y, xr
