"""Pallas TPU kernel: fused sup-row update (TRSV + GEMV in one VMEM pass).

HYLU's level-2 kernel: "the sup-row kernel still updates a row at a time,
but uses supernodes as source data ... level-2 BLAS can be called".  On TPU
a standalone row is a (1, w) panel; fusing the triangular solve and the
panel GEMV in one kernel keeps the row slice and the source panel resident
in VMEM for the whole update (one HBM round-trip instead of two).

The source panel is tiled over its width m (lane dim); the k×k diag block
and the row are resident.  Grid: (m/TN,) with the TRSV done on the first
grid step into a VMEM scratch shared by later steps.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _suprow_kernel(xk_ref, xm_ref, u_ref, b_ref, y_ref, xr_ref, y_scr, *,
                   k: int):
    @pl.when(pl.program_id(0) == 0)
    def _trsv():
        u = u_ref[...]
        x = xk_ref[...]                       # (1, k)

        def body(j, y):
            acc = x[0, j] - y[0] @ u[:, j]
            return y.at[0, j].set(acc / u[j, j])

        y = jax.lax.fori_loop(0, k, body, jnp.zeros_like(x))
        y_scr[...] = y
        y_ref[...] = y

    y = y_scr[...]
    xr_ref[...] = xm_ref[...] - y @ b_ref[...]     # GEMV tile


@functools.partial(jax.jit, static_argnames=("tn", "interpret"))
def suprow_update_p(xk: jax.Array, xm: jax.Array, u: jax.Array, b: jax.Array,
                    tn: int = 512, interpret: bool = True):
    """xk: (1,k) row head; xm: (1,m) row tail; u: (k,k); b: (k,m)."""
    k = u.shape[0]
    m = xm.shape[1]
    tn = min(tn, m)
    grid = (pl.cdiv(m, tn),)
    return pl.pallas_call(
        functools.partial(_suprow_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((1, tn), lambda j: (0, j)),
            pl.BlockSpec((k, k), lambda j: (0, 0)),
            pl.BlockSpec((k, tn), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((1, tn), lambda j: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), xk.dtype),
            jax.ShapeDtypeStruct((1, m), xm.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, k), xk.dtype)],
        interpret=interpret,
    )(xk, xm, u, b)
