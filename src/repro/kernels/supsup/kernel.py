"""Pallas TPU kernel: sup-sup trailing update  C -= A @ B  (MXU GEMM).

This is HYLU's level-3-BLAS kernel mapped to the MXU: a supernode's dense
U-panel B (k × m) updates a target panel slice C (nr × m) through the just
solved multipliers A (nr × k).  The gather/scatter through ``col_map``
happens outside (XLA gather fuses with the kernel's HBM reads on TPU); the
kernel is the flop-dominant GEMM with explicit VMEM tiling:

  grid (i, j, l) over (nr/TM, m/TN, k/TK); C tile accumulated in a VMEM
  scratch accumulator across the contraction dimension l (arbitrary-order
  innermost axis), written back on the last l step.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_update_kernel(c_ref, a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = c_ref[...].astype(acc_ref.dtype)

    acc_ref[...] -= jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _bmm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tn", "tk", "interpret"))
def bmm(a: jax.Array, b: jax.Array, tn: int = 128, tk: int = 128,
        interpret: bool = True) -> jax.Array:
    """Batched A @ B for the bucketed sup-sup update: a (E, nr, k),
    b (E, k, m) → (E, nr, m).  The leading bucket dim is the outer Pallas
    grid axis; each bucket member's GEMM tiles its m/k dims into VMEM with
    a scratch accumulator over the contraction axis (the nr dim of one
    supernode edge is ≤ 128 and stays whole)."""
    E, nr, k = a.shape
    m = b.shape[2]
    tn, tk = min(tn, m), min(tk, k)
    grid = (E, pl.cdiv(m, tn), pl.cdiv(k, tk))
    return pl.pallas_call(
        functools.partial(_bmm_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nr, tk), lambda e, j, l: (e, 0, l)),   # A
            pl.BlockSpec((1, tk, tn), lambda e, j, l: (e, l, j)),   # B
        ],
        out_specs=pl.BlockSpec((1, nr, tn), lambda e, j, l: (e, 0, j)),
        out_shape=jax.ShapeDtypeStruct((E, nr, m), a.dtype),
        # fp32 accumulation on the MXU; f64 only in CPU-interpret testing
        scratch_shapes=[pltpu.VMEM(
            (nr, tn), jnp.float64 if a.dtype == jnp.float64 else jnp.float32)],
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit,
                   static_argnames=("tm", "tn", "tk", "interpret"))
def gemm_update(c: jax.Array, a: jax.Array, b: jax.Array,
                tm: int = 128, tn: int = 128, tk: int = 128,
                interpret: bool = True) -> jax.Array:
    """C - A @ B with VMEM tiling. c: (nr, m), a: (nr, k), b: (k, m)."""
    nr, m = c.shape
    k = a.shape[1]
    tm, tn, tk = min(tm, nr), min(tn, m), min(tk, k)
    grid = (pl.cdiv(nr, tm), pl.cdiv(m, tn), pl.cdiv(k, tk))
    return pl.pallas_call(
        functools.partial(_gemm_update_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),   # C
            pl.BlockSpec((tm, tk), lambda i, j, l: (i, l)),   # A
            pl.BlockSpec((tk, tn), lambda i, j, l: (l, j)),   # B
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nr, m), c.dtype),
        # fp32 accumulation on the MXU; f64 only in CPU-interpret testing
        scratch_shapes=[pltpu.VMEM(
            (tm, tn), jnp.float64 if c.dtype == jnp.float64 else jnp.float32)],
        interpret=interpret,
    )(c, a, b)
