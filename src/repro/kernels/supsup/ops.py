"""jit'd wrapper for the sup-sup update (TRSM + GEMM Pallas kernels)."""
import jax
import jax.numpy as jnp

from repro.kernels.trisolve import ops as trisolve_ops
from .kernel import bmm, gemm_update
from .ref import supsup_update_ref, gemm_update_ref

__all__ = ["supsup_update", "gemm", "gemm_batched", "supsup_update_ref",
           "gemm_update_ref"]


def supsup_update(x: jax.Array, src: jax.Array, k: int,
                  interpret: bool = True):
    """The full sup-sup numeric update on a gathered panel slice.

    x:   (nr, k+m) target panel slice (gathered through col_map)
    src: (k, k+m)  source supernode rows (diag block + U panel)
    Returns (lts, xr): the solved multipliers and the updated trailing part.
    """
    lts = trisolve_ops.trsm(src[:, :k], x[:, :k], interpret=interpret)
    xr = gemm(x[:, k:], lts, src[:, k:], interpret=interpret)
    return lts, xr


def gemm_batched(a: jax.Array, b: jax.Array,
                 interpret: bool = True) -> jax.Array:
    """Batched A @ B (the trailing-update GEMM of one bucketed sup-sup
    edge application): a (E, nr, k), b (E, k, m) → (E, nr, m), padding
    nr/k/m to sublane/lane multiples.  Zero-padding is exact: padded rows
    and columns of the product land in scatter positions the engine
    directs at its scratch slot."""
    e, nr, k = a.shape
    m = b.shape[2]
    if m == 0 or k == 0:
        return jnp.zeros((e, nr, m), a.dtype)

    def rnd(v, mult=8):
        return max(mult, -(-v // mult) * mult)

    nrp, mp, kp = rnd(nr), rnd(m, 128 if m >= 128 else 8), rnd(k)
    if (nrp, mp, kp) != (nr, m, k):
        ap = jnp.zeros((e, nrp, kp), a.dtype).at[:, :nr, :k].set(a)
        bp = jnp.zeros((e, kp, mp), b.dtype).at[:, :k, :m].set(b)
        return bmm(ap, bp, interpret=interpret)[:, :nr, :m]
    return bmm(a, b, interpret=interpret)


def gemm(c: jax.Array, a: jax.Array, b: jax.Array,
         interpret: bool = True) -> jax.Array:
    """C - A @ B, padding every dim to sublane/lane multiples (8 / 128-ish;
    small solver panels use 8-multiples to bound padding waste)."""
    nr, m = c.shape
    k = a.shape[1]
    if m == 0 or k == 0:
        return c

    def rnd(v, mult=8):
        return max(mult, -(-v // mult) * mult)

    nrp, mp, kp = rnd(nr), rnd(m, 128 if m >= 128 else 8), rnd(k)
    if (nrp, mp, kp) != (nr, m, k):
        cp = jnp.zeros((nrp, mp), c.dtype).at[:nr, :m].set(c)
        ap = jnp.zeros((nrp, kp), a.dtype).at[:nr, :k].set(a)
        bp = jnp.zeros((kp, mp), b.dtype).at[:k, :m].set(b)
        return gemm_update(cp, ap, bp, interpret=interpret)[:nr, :m]
    return gemm_update(c, a, b, interpret=interpret)
