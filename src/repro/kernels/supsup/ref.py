"""Pure-jnp oracle for the sup-sup update: TRSM + GEMM trailing update.

    lts = X[:, :k] @ inv(U_SS)          (U_SS = src[:, :k], upper-tri)
    xr  = X[:, k:] - lts @ src[:, k:]
"""
import jax.numpy as jnp

from repro.kernels.trisolve.ref import trsm_upper_ref


def supsup_update_ref(x, src, k):
    lts = trsm_upper_ref(src[:, :k], x[:, :k])
    xr = x[:, k:] - lts @ src[:, k:]
    return lts, xr


def gemm_update_ref(c, a, b):
    """C - A @ B (the trailing update in isolation)."""
    return c - a @ b
