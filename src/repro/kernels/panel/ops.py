"""jit'd wrappers for the panel-LU Pallas kernels (scalar + bucketed).

Dtype contract: the kernels run entirely in the panel dtype (float64 /
float32 / bfloat16) — masks and the perturbation threshold are cast to it,
and the identity-pivot sentinel (1e30) is representable in every supported
dtype, so the same kernels serve the mixed-precision engine unchanged.
``eps_p`` should already be scaled to the dtype's machine epsilon
(``repro.core.options.resolve_perturb_eps``).
"""
import jax
import jax.numpy as jnp

from .kernel import panel_lu_bucketed_p, panel_lu_p
from .ref import panel_lu_bucketed_ref, panel_lu_ref

__all__ = ["panel_lu", "panel_lu_batched", "panel_lu_ref",
           "panel_lu_bucketed_ref"]


def _eps_in(dtype, eps_p):
    """``eps_p`` cast to the panel dtype, guarded against underflow: a
    positive threshold that downcasts to zero (bfloat16 underflows near
    1e-38) would silently disable pivot perturbation and let exact-zero
    pivots produce inf/NaN panels — clamp it to the dtype's smallest
    normal instead.  An exactly-zero eps (perturbation off) stays zero."""
    eps0 = jnp.asarray(eps_p)
    eps = eps0.astype(dtype)
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    return jnp.where((eps0 > 0) & (eps <= 0), tiny, eps)


def panel_lu(panel: jax.Array, nr: int, lsize: int, eps_p,
             interpret: bool = True):
    """Returns (panel, local_perm (int32 nr), n_perturb (int32 scalar))."""
    eps = _eps_in(panel.dtype, eps_p)
    out, perm, nper = panel_lu_p(panel, eps, nr, lsize, interpret=interpret)
    return out, perm, nper[0]


def panel_lu_batched(panels: jax.Array, wu: int, eps_p,
                     interpret: bool = True):
    """Bucketed panel LU on column-reordered panels (B, nr, wt): the
    leading bucket dim is the Pallas grid, elimination masked to [0, wu).
    Returns (panels, perms (B, nr) int32, n_perturb (B,) int32)."""
    eps = _eps_in(panels.dtype, eps_p)
    return panel_lu_bucketed_p(panels, eps, wu, interpret=interpret)
