"""jit'd wrappers for the panel-LU Pallas kernels (scalar + bucketed)."""
import jax
import jax.numpy as jnp

from .kernel import panel_lu_bucketed_p, panel_lu_p
from .ref import panel_lu_bucketed_ref, panel_lu_ref

__all__ = ["panel_lu", "panel_lu_batched", "panel_lu_ref",
           "panel_lu_bucketed_ref"]


def panel_lu(panel: jax.Array, nr: int, lsize: int, eps_p,
             interpret: bool = True):
    """Returns (panel, local_perm (int32 nr), n_perturb (int32 scalar))."""
    eps = jnp.asarray(eps_p, dtype=panel.dtype)
    out, perm, nper = panel_lu_p(panel, eps, nr, lsize, interpret=interpret)
    return out, perm, nper[0]


def panel_lu_batched(panels: jax.Array, wu: int, eps_p,
                     interpret: bool = True):
    """Bucketed panel LU on column-reordered panels (B, nr, wt): the
    leading bucket dim is the Pallas grid, elimination masked to [0, wu).
    Returns (panels, perms (B, nr) int32, n_perturb (B,) int32)."""
    eps = jnp.asarray(eps_p, dtype=panels.dtype)
    return panel_lu_bucketed_p(panels, eps, wu, interpret=interpret)
