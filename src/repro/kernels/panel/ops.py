"""jit'd wrapper for the panel-LU Pallas kernel."""
import jax
import jax.numpy as jnp

from .kernel import panel_lu_p
from .ref import panel_lu_ref

__all__ = ["panel_lu", "panel_lu_ref"]


def panel_lu(panel: jax.Array, nr: int, lsize: int, eps_p,
             interpret: bool = True):
    """Returns (panel, local_perm (int32 nr), n_perturb (int32 scalar))."""
    eps = jnp.asarray(eps_p, dtype=panel.dtype)
    out, perm, nper = panel_lu_p(panel, eps, nr, lsize, interpret=interpret)
    return out, perm, nper[0]
