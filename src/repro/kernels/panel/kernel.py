"""Pallas TPU kernel: in-supernode dense LU (right-looking, in-VMEM).

The internal factorization of a supernode: partial pivoting restricted to
the diagonal block (HYLU's supernode diagonal pivoting — legal because the
rows of a supernode share their U structure) + pivot perturbation for
small/zero pivots (SuperLU_DIST-style, ref [13] of the paper).

The whole panel (nr ≤ 128 rows × w cols) is one VMEM block: the supernode
width cap chosen at analysis time guarantees it fits.  The perturbation
threshold eps_p is a runtime scalar ((1,1) VMEM input) because it depends
on max|B| of the current values (refactorization changes it without
recompiling).

Outputs: factored panel, local pivot permutation, #perturbed pivots.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _panel_lu_kernel(panel_ref, eps_ref, out_ref, perm_ref, nper_ref, *,
                     nr: int, lsize: int):
    panel = panel_ref[...]
    eps_p = eps_ref[0, 0]
    w = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.zeros((), jnp.int32)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, lsize + j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, lsize + j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, lsize + j].set(piv)
        nper = nper + small.astype(jnp.int32)
        l = panel[:, lsize + j] / piv
        l = l * (rows > j).astype(panel.dtype)
        urow = panel[j, :] * (jnp.arange(w) > lsize + j).astype(panel.dtype)
        panel = panel - l[:, None] * urow[None, :]       # VPU rank-1
        panel = panel.at[:, lsize + j].set(
            jnp.where(rows > j, l, panel[:, lsize + j]))
        return panel, perm, nper

    panel, perm, nper = jax.lax.fori_loop(0, nr, body, (panel, perm, nper))
    out_ref[...] = panel
    perm_ref[...] = perm.reshape(perm_ref.shape)
    nper_ref[...] = nper.reshape(nper_ref.shape)


def _panel_lu_bucketed_kernel(panel_ref, eps_ref, out_ref, perm_ref,
                              nper_ref, *, nr: int, wu: int):
    """One bucket member per grid step: dense LU of a column-reordered
    panel [diag block | U suffix | L prefix].  Elimination is masked to the
    static window [0, wu); trailing (prefix) columns only row-swap.  Padded
    block diagonals are identity (set up by the gather map), so padded
    pivot steps are exact no-ops and never count as perturbations."""
    panel = panel_ref[0]
    eps_p = eps_ref[0, 0]
    wt = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.zeros((), jnp.int32)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, j].set(piv)
        nper = nper + small.astype(jnp.int32)
        l = panel[:, j] / piv
        l = l * (rows > j).astype(panel.dtype)
        cmask = ((jnp.arange(wt) > j) & (jnp.arange(wt) < wu))
        urow = panel[j, :] * cmask.astype(panel.dtype)
        panel = panel - l[:, None] * urow[None, :]       # VPU rank-1
        panel = panel.at[:, j].set(
            jnp.where(rows > j, l, panel[:, j]))
        return panel, perm, nper

    panel, perm, nper = jax.lax.fori_loop(0, nr, body, (panel, perm, nper))
    out_ref[0] = panel
    perm_ref[0] = perm
    nper_ref[0] = nper.reshape(nper_ref.shape[1:])


@functools.partial(jax.jit, static_argnames=("wu", "interpret"))
def panel_lu_bucketed_p(panels: jax.Array, eps_p: jax.Array, wu: int,
                        interpret: bool = True):
    """Bucketed panel LU: panels (B, nr, wt), one grid step per bucket
    member (the leading bucket dim is the Pallas grid).  Returns the
    factored panels, per-panel local pivot permutations (B, nr) and
    per-panel perturbation counts (B,)."""
    B, nr, wt = panels.shape
    eps2d = jnp.reshape(eps_p.astype(panels.dtype), (1, 1))
    out, perm, nper = pl.pallas_call(
        functools.partial(_panel_lu_bucketed_kernel, nr=nr, wu=wu),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, nr, wt), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nr, wt), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, nr), lambda b: (b, 0)),
            pl.BlockSpec((1, 1), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nr, wt), panels.dtype),
            jax.ShapeDtypeStruct((B, nr), jnp.int32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(panels, eps2d)
    return out, perm, nper[:, 0]


@functools.partial(jax.jit, static_argnames=("nr", "lsize", "interpret"))
def panel_lu_p(panel: jax.Array, eps_p: jax.Array, nr: int, lsize: int,
               interpret: bool = True):
    w = panel.shape[1]
    eps2d = jnp.reshape(eps_p.astype(panel.dtype), (1, 1))
    return pl.pallas_call(
        functools.partial(_panel_lu_kernel, nr=nr, lsize=lsize),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((nr, w), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((nr, w), lambda i: (0, 0)),
            pl.BlockSpec((nr,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nr, w), panel.dtype),
            jax.ShapeDtypeStruct((nr,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(panel, eps2d)
