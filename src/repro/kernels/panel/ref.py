"""Pure-jnp oracle for the panel kernel: dense LU of a supernode's diagonal
block with partial pivoting inside the block (supernode diagonal pivoting)
and pivot perturbation. Operates on the full panel so row swaps carry the
L-part and U-part along, exactly like the engine."""
import jax
import jax.numpy as jnp


def panel_lu_ref(panel: jax.Array, nr: int, lsize: int, eps_p):
    """panel: (nr, w). Returns (panel, local_perm, n_perturb)."""
    w = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.int32(0)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, lsize + j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, lsize + j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, lsize + j].set(piv)
        nper = nper + small.astype(jnp.int32)
        l = panel[:, lsize + j] / piv
        l = l * (rows > j).astype(panel.dtype)
        urow = panel[j, :] * (jnp.arange(w) > lsize + j).astype(panel.dtype)
        panel = panel - jnp.outer(l, urow)
        panel = panel.at[:, lsize + j].set(
            jnp.where(rows > j, l, panel[:, lsize + j]))
        return panel, perm, nper

    return jax.lax.fori_loop(0, nr, body, (panel, perm, nper))


def panel_lu_bucketed_ref(panels: jax.Array, wu: int, eps_p):
    """Oracle for the bucketed kernel: B independent LUs of column-reordered
    panels (B, nr, wt), elimination masked to the window [0, wu) (trailing
    columns — the L prefix — only row-swap).  Returns
    (panels, perms (B, nr), n_perturb (B,))."""
    B, nr, wt = panels.shape
    rows = jnp.arange(nr)
    colr = jnp.arange(wt)
    perm = jnp.broadcast_to(rows.astype(jnp.int32), (B, nr))
    nper = jnp.zeros((B,), jnp.int32)

    def body(j, carry):
        P, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(P, j, 1, axis=2)[:, :, 0]
        cand = jnp.where(rows[None, :] >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand, axis=1)
        base = jnp.broadcast_to(rows, (B, nr))
        swap = base.at[:, j].set(p)
        swap = jnp.where(base == p[:, None], j, swap)
        P = jnp.take_along_axis(P, swap[:, :, None], axis=1)
        perm = jnp.take_along_axis(perm, swap, axis=1)
        piv = P[:, j, j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        P = P.at[:, j, j].set(piv)
        nper = nper + small.astype(jnp.int32)
        l = P[:, :, j] / piv[:, None]
        l = l * (rows[None, :] > j).astype(P.dtype)
        urow = P[:, j, :] * ((colr > j) & (colr < wu)).astype(P.dtype)[None, :]
        P = P - l[:, :, None] * urow[:, None, :]
        P = P.at[:, :, j].set(jnp.where(rows[None, :] > j, l, P[:, :, j]))
        return P, perm, nper

    return jax.lax.fori_loop(0, nr, body, (panels, perm, nper))
