"""Pure-jnp oracle for the panel kernel: dense LU of a supernode's diagonal
block with partial pivoting inside the block (supernode diagonal pivoting)
and pivot perturbation. Operates on the full panel so row swaps carry the
L-part and U-part along, exactly like the engine."""
import jax
import jax.numpy as jnp


def panel_lu_ref(panel: jax.Array, nr: int, lsize: int, eps_p):
    """panel: (nr, w). Returns (panel, local_perm, n_perturb)."""
    w = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.int32(0)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, lsize + j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, lsize + j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, lsize + j].set(piv)
        nper = nper + small.astype(jnp.int32)
        l = panel[:, lsize + j] / piv
        l = l * (rows > j).astype(panel.dtype)
        urow = panel[j, :] * (jnp.arange(w) > lsize + j).astype(panel.dtype)
        panel = panel - jnp.outer(l, urow)
        panel = panel.at[:, lsize + j].set(
            jnp.where(rows > j, l, panel[:, lsize + j]))
        return panel, perm, nper

    return jax.lax.fori_loop(0, nr, body, (panel, perm, nper))
