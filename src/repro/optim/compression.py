"""Gradient compression for cross-pod data parallelism (distributed-
optimization trick for the 1000+-node regime).

Cross-pod gradient all-reduce is DCI-bandwidth-bound; compressing the
cross-pod reduction with error feedback (1-bit Adam / EF21 family) trades
a cheap local correction for 2–16× less inter-pod traffic.

Implementation: hook applied to grads *before* the optimizer —
  compress → (pseudo-)all-reduce over 'pod' → decompress + error feedback.
Inside jit/GSPMD the all-reduce emerges from psum over the pod axis when
run under shard_map; in the plain pjit path XLA already reduced over data
axes, so the hook degrades to quantize+dequantize with error feedback
(accuracy-preserving, bandwidth win realized under shard_map deployment).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | bf16 | int8
    error_feedback: bool = True


def init_error_state(params, cfg: CompressionConfig):
    if cfg.kind == "none" or not cfg.error_feedback:
        return None
    return jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)


def _quant_int8(g):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(cfg: CompressionConfig, grads, err_state):
    """Returns (compressed-then-decompressed grads, new error state).
    The quantized representation is what crosses the pod link."""
    if cfg.kind == "none":
        return grads, err_state

    def one(g, e):
        g32 = g.astype(F32) + (e if e is not None else 0.0)
        if cfg.kind == "bf16":
            gq = g32.astype(jnp.bfloat16).astype(F32)
        elif cfg.kind == "int8":
            q, scale = _quant_int8(g32)
            gq = q.astype(F32) * scale
        else:
            raise ValueError(cfg.kind)
        new_e = (g32 - gq) if cfg.error_feedback else None
        return gq.astype(g.dtype), new_e

    if err_state is None:
        flat_g, tdef = jax.tree.flatten(grads)
        out = [one(g, None) for g in flat_g]
        return tdef.unflatten([o[0] for o in out]), None
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))
