"""AdamW + global-norm clipping + schedules (optax is unavailable offline;
this is the framework's own optimizer substrate).

State layout mirrors the param tree (m, v in fp32), sharded with the same
PartitionSpecs as the params — i.e. ZeRO-style: TP-sharded params get
TP-sharded optimizer states for free, FSDP'd params get FSDP'd states.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init_state(params) -> AdamWState:
    z = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(z, params), v=jax.tree.map(z, params))


def lr_schedule(cfg: AdamWConfig, step):
    step = step.astype(F32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(F32)
    b2c = 1 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), dict(grad_norm=gnorm, lr=lr)
