"""Roofline terms from the compiled dry-run artifact (TPU v5e targets).

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes / collective_bytes are totals over chips; our parser
reads the per-device SPMD module, so total = per_device × chips and each
term reduces to per_device_quantity / per_chip_rate.  MODEL_FLOPS = 6·N·D
(dense) or 6·N_active·D (MoE) for train; 2·N·D for inference steps.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from . import hlo_cost

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities from the compiled HLO
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_by_kind: dict
    # terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float           # 6·N·D (or inference 2·N·D), global
    hlo_flops_total: float
    useful_ratio: float          # model_flops / hlo_flops_total
    # memory analysis
    bytes_args: float = 0.0
    bytes_out: float = 0.0
    bytes_temp: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def model_flops(cfg: ArchConfig, shape_kind: str, n_tokens: float) -> float:
    n = cfg.active_param_count()
    if shape_kind == "train":
        return 6.0 * n * n_tokens
    return 2.0 * n * n_tokens


def compute(arch: ArchConfig, shape_name: str, shape_kind: str, mesh_name: str,
            chips: int, hlo_text: str, n_tokens: float,
            mem_stats=None) -> Roofline:
    c = hlo_cost.analyze(hlo_text)
    t_comp = c.flops / PEAK_FLOPS
    t_mem = c.bytes_accessed / HBM_BW
    t_coll = c.coll_bytes / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bott = max(terms, key=terms.get)
    mf = model_flops(arch, shape_kind, n_tokens)
    hlo_total = c.flops * chips
    r = Roofline(
        arch=arch.name, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=c.flops, bytes_per_device=c.bytes_accessed,
        coll_bytes_per_device=c.coll_bytes, coll_by_kind=dict(c.coll_by_kind),
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bott, model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
    )
    if mem_stats is not None:
        r.bytes_args = float(mem_stats.argument_size_in_bytes)
        r.bytes_out = float(mem_stats.output_size_in_bytes)
        r.bytes_temp = float(mem_stats.temp_size_in_bytes)
    return r
