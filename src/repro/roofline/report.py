"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from dryrun JSON.

    PYTHONPATH=src python -m repro.roofline.report artifacts/dryrun/dryrun_single_multi.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x is None:
        return "—"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def render(records, mesh_filter="pod16x16"):
    lines = []
    lines.append("| arch | shape | t_compute | t_memory | t_collective | "
                 "bottleneck | HLO flops/dev | useful ratio | temp GiB |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in records:
        if r.get("status") != "ok" or r.get("mesh") != mesh_filter:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"**{r['bottleneck']}** | {r['flops_per_device']:.2e} | "
            f"{r['useful_ratio']:.2f} | {r['mem_temp_gib']:.2f} |")
    skips = [r for r in records if r.get("status") == "skipped"
             and r.get("mesh") == mesh_filter]
    if skips:
        lines.append("")
        lines.append("Skipped cells (documented in DESIGN.md "
                     "§Arch-applicability):")
        for r in skips:
            lines.append(f"- {r['arch']} × {r['shape']}")
    return "\n".join(lines)


def render_dryrun_summary(records):
    n_ok = sum(1 for r in records if r.get("status") == "ok")
    n_skip = sum(1 for r in records if r.get("status") == "skipped")
    n_err = len(records) - n_ok - n_skip
    lines = [f"Cells: {n_ok} compiled ok, {n_skip} documented skips, "
             f"{n_err} errors."]
    lines.append("")
    lines.append("| arch | shape | mesh | compile s | temp GiB | args GiB | "
                 "coll bytes/dev | coll ops |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in records:
        if r.get("status") != "ok":
            continue
        kinds = r.get("coll_by_kind", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('t_compile_s', 0):.1f} | {r['mem_temp_gib']:.2f} | "
            f"{r.get('mem_args_gib', 0):.2f} | "
            f"{r['coll_bytes_per_device']:.2e} | "
            f"{'+'.join(k for k in sorted(kinds))} |")
    return "\n".join(lines)


def main(argv=None):
    path = (argv or sys.argv[1:])[0]
    with open(path) as f:
        records = json.load(f)
    print("## §Dry-run\n")
    print(render_dryrun_summary(records))
    print("\n## §Roofline (single-pod 16×16, per cell)\n")
    print(render(records, "pod16x16"))
    print("\n## §Roofline (multi-pod 2×16×16)\n")
    print(render(records, "pod2x16x16"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
