"""HLO-text cost model with while-loop trip-count multiplication.

XLA's ``compiled.cost_analysis()`` visits a while body ONCE (verified
empirically: a 7-iteration scan reports 1 body's FLOPs), which silently
under-counts every scan-over-layers model by ~n_layers×.  This parser walks
the compiled per-device HLO text, computes

  - dot/convolution FLOPs (2·|out|·K) + elementwise FLOPs,
  - bytes accessed (operands + outputs per top-level op; fusions opaque),
  - collective bytes per opcode (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute, incl. -start forms),

per computation, then folds the call graph with multiplicities:
``while`` bodies × known_trip_count (backend_config), fusion/call/reduce
bodies × 1, conditionals × max over branches.

Validated against cost_analysis() on loop-free programs (tests).
All numbers are per-device (the HLO is the post-SPMD per-device module).
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "s4": 1, "u4": 1, "f4e2m1fn": 1,
    "f8e8m0fnu": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
}

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "floor", "ceil", "sign", "cosine", "sine", "logistic", "expm1",
    "log1p", "atan2", "remainder", "cbrt", "erf",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|to_apply|branch_computations)=%?"
                       r"(\{[^}]*\}|[\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")


def _shape_bytes_elems(type_str: str):
    """Parse 'f32[2,3]{...}' or tuple '(f32[2], s32[])'. Returns
    (bytes, elems_of_first_array)."""
    total = 0
    first_elems = None
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * DTYPE_BYTES[dt]
        if first_elems is None:
            first_elems = elems
    return total, (first_elems or 0)


@dataclasses.dataclass
class OpInfo:
    name: str
    type_str: str
    opcode: str
    rest: str           # operand list + attributes (raw tail of the line)


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_count: int = 0


def parse_computations(hlo_text: str) -> dict:
    """name -> list[OpInfo] (top-level ops only)."""
    comps: dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            cur = mc.group(1)
            comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mo = _OP_RE.match(line)
        if mo:
            comps[cur].append(OpInfo(name=mo.group(1), type_str=mo.group(2),
                                     opcode=mo.group(3), rest=mo.group(4)))
    return comps


def _operand_names(rest: str) -> list:
    """Names inside the top-level parens of `opcode(...)`."""
    depth = 0
    end = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    inner = rest[:end]
    return re.findall(r"%([\w\.\-]+)", inner)


def _dot_flops(op: OpInfo, shapes: dict) -> float:
    _, out_elems = _shape_bytes_elems(op.type_str)
    ops = _operand_names(op.rest)
    if not ops:
        return 0.0
    lhs_shape = shapes.get(ops[0])
    if lhs_shape is None:
        return 0.0
    mdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    k = 1
    if mdims and mdims.group(1):
        for d in mdims.group(1).split(","):
            k *= lhs_shape[int(d)]
    mbatch = re.search(r"lhs_batch_dims=\{([\d,]*)\}", op.rest)
    # out already includes batch dims; flops = 2 * out * k
    return 2.0 * out_elems * k


def _conv_flops(op: OpInfo, shapes: dict) -> float:
    _, out_elems = _shape_bytes_elems(op.type_str)
    ops = _operand_names(op.rest)
    if len(ops) < 2:
        return 0.0
    ker = shapes.get(ops[1])
    if ker is None:
        return 0.0
    # rough: 2 * out * prod(kernel dims except output-feature dim)
    kprod = 1
    for d in ker:
        kprod *= d
    mdim = re.search(r"dim_labels=[\w\?]*_([\w\?]*)->", op.rest)
    out_feat = 1
    if mdim:
        lab = mdim.group(1)
        pos = lab.find("o")
        if pos >= 0:
            out_feat = ker[pos]
    return 2.0 * out_elems * kprod / max(out_feat, 1)


def _shapes_table(ops: list) -> dict:
    table = {}
    for op in ops:
        dims = []
        m = _SHAPE_RE.search(op.type_str)
        if m:
            dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        table[op.name] = dims
    return table


def analyze(hlo_text: str, entry: str | None = None) -> CompCost:
    comps = parse_computations(hlo_text)
    if not comps:
        return CompCost()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, flags=re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, CompCost] = {}

    def comp_cost(name: str) -> CompCost:
        if name in memo:
            return memo[name]
        memo[name] = CompCost()           # cycle guard
        ops = comps.get(name, [])
        shapes = _shapes_table(ops)
        info = {op.name: _shape_bytes_elems(op.type_str) for op in ops}
        c = CompCost(coll_by_kind=defaultdict(float))
        for op in ops:
            out_bytes, out_elems = _shape_bytes_elems(op.type_str)
            opnames = _operand_names(op.rest)
            in_bytes = sum(info[on][0] for on in opnames if on in info)
            opc = op.opcode
            if opc in ("parameter", "constant", "get-tuple-element", "tuple",
                       "bitcast", "after-all"):
                continue
            if opc in ("gather", "dynamic-slice"):
                # only touched elements move (not the whole operand); XLA's
                # own cost model has the same full-operand overcount.
                idx_bytes = sum(info[on][0] for on in opnames[1:] if on in info)
                c.bytes_accessed += 2 * out_bytes + idx_bytes
            elif opc in ("scatter", "dynamic-update-slice"):
                upd = (info[opnames[-1]][0]
                       if opnames and opnames[-1] in info else out_bytes)
                c.bytes_accessed += 3 * upd   # read+write target region + upd
            else:
                c.bytes_accessed += in_bytes + out_bytes
            if opc == "dot":
                c.flops += _dot_flops(op, shapes)
            elif opc == "convolution":
                c.flops += _conv_flops(op, shapes)
            elif opc in ELEMENTWISE:
                c.flops += out_elems
                if opc in ("exponential", "log", "tanh", "rsqrt", "sqrt",
                           "power", "logistic", "cosine", "sine", "erf"):
                    c.transcendentals += out_elems
            elif opc == "reduce" or opc == "reduce-window":
                c.flops += sum(info[on][1] for on in opnames[:1] if on in info)
            base = opc[:-6] if opc.endswith("-start") else opc
            if base in COLLECTIVES:
                cb = in_bytes
                c.coll_bytes += cb
                c.coll_by_kind[base] += cb
                c.coll_count += 1
            # called computations
            trip = 1
            if opc == "while":
                mt = _TRIP_RE.search(op.rest)
                trip = int(mt.group(1)) if mt else 1
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if mb and mb.group(1) in comps:
                    _fold(c, comp_cost(mb.group(1)), trip)
                mc2 = _COND_RE.search(op.rest)
                if mc2 and mc2.group(1) in comps:
                    _fold(c, comp_cost(mc2.group(1)), trip + 1)
            elif opc == "fusion":
                mcalls = re.search(r"calls=%?([\w\.\-]+)", op.rest)
                if mcalls and mcalls.group(1) in comps:
                    sub = comp_cost(mcalls.group(1))
                    # fusion: flops inside count; bytes stay opaque (already
                    # counted as operands+output above)
                    c.flops += sub.flops
                    c.transcendentals += sub.transcendentals
                    _fold_coll(c, sub, 1)
            elif opc in ("call", "custom-call", "reduce", "sort", "scatter",
                         "select-and-scatter", "map", "reduce-window"):
                mcalls = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.rest)
                if mcalls and mcalls.group(1) in comps:
                    sub = comp_cost(mcalls.group(1))
                    _fold_coll(c, sub, 1)
            elif opc == "conditional":
                mbr = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                if mbr:
                    subs = [comp_cost(b.strip().lstrip("%"))
                            for b in mbr.group(1).split(",")]
                    if subs:
                        best = max(subs, key=lambda s: s.flops)
                        _fold(c, best, 1)
        memo[name] = c
        return c

    def _fold(dst: CompCost, src: CompCost, mult: int):
        dst.flops += src.flops * mult
        dst.transcendentals += src.transcendentals * mult
        dst.bytes_accessed += src.bytes_accessed * mult
        _fold_coll(dst, src, mult)

    def _fold_coll(dst: CompCost, src: CompCost, mult: int):
        dst.coll_bytes += src.coll_bytes * mult
        dst.coll_count += src.coll_count * mult
        for k2, v2 in src.coll_by_kind.items():
            dst.coll_by_kind[k2] = dst.coll_by_kind.get(k2, 0.0) + v2 * mult

    total = comp_cost(entry)
    total.coll_by_kind = dict(total.coll_by_kind)
    return total
