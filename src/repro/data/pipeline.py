"""Deterministic, resumable, shard-aware token pipeline.

Two sources:
  - SyntheticLM: seeded Zipf-ish token stream (benchmarks/smoke);
  - MemmapDataset: flat binary token file (np.memmap), the production path.

Determinism/resume: batch content is a pure function of (seed, step), so
restart-from-checkpoint replays the exact stream without state files.
Sharding: each data-parallel group reads only its slice (host offset), the
returned global batch is laid out so jax.device_put with the batch sharding
scatters the right rows to the right devices.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        # Zipf-ish marginal + a deterministic n-gram-ish structure so the
        # loss actually decreases during example training runs
        z = rng.zipf(1.3, size=(self.global_batch, self.seq_len + 1))
        toks = (z % self.vocab).astype(np.int32)
        toks[:, 1:] = (toks[:, 1:] + toks[:, :-1] * 7) % self.vocab
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:].copy())


@dataclasses.dataclass
class MemmapDataset:
    path: str
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=np.int32, mode="r")
        self.n_tokens = len(self._data)
        self.n_windows = (self.n_tokens - 1) // self.seq_len

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, self.n_windows, size=self.global_batch)
        starts = idx * self.seq_len
        toks = np.stack([self._data[s:s + self.seq_len + 1] for s in starts])
        toks = toks.astype(np.int32)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:].copy())


def write_synthetic_corpus(path: str, n_tokens: int, vocab: int, seed=0):
    rng = np.random.default_rng(seed)
    data = (rng.zipf(1.3, size=n_tokens) % vocab).astype(np.int32)
    data.tofile(path)
    return path
