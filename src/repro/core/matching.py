"""Static pivoting: maximum-weight bipartite matching + two-sided scaling.

This is the MC64 job (Duff & Koster, "On Algorithms For Permuting Large
Entries to the Diagonal of a Sparse Matrix", SIAM J. Matrix Anal. 2001),
HYLU preprocessing step 1.  We maximize prod |a_{i,sigma(i)}| which is the
assignment problem with costs

    c_ij = log(max_j' |a_ij'|) - log |a_ij|   >= 0        (row-wise maxima)

solved by shortest augmenting paths (Dijkstra) with dual potentials — the
same algorithm family as MC64.  The optimal duals (u, v) give the scaling

    r_i = exp(-u_i) / max_i          s_j = exp(-v_j)

such that B = diag(r) A diag(s) has |b_{i,sigma(i)}| = 1 and |b_ij| <= 1.

A vectorized greedy pre-pass matches the (very common) rows whose maximum
entry sits in an unclaimed column, so well-conditioned circuit matrices cost
O(nnz) here and only degenerate rows pay for Dijkstra.
"""
from __future__ import annotations

import dataclasses
import heapq
import numpy as np

from .matrix import CSR

_BIG = 1e100


@dataclasses.dataclass
class MatchResult:
    col_of_row: np.ndarray   # sigma: row i matched to column sigma[i] (-1 none)
    row_scale: np.ndarray    # r
    col_scale: np.ndarray    # s
    structurally_singular: bool


def _log_costs(a: CSR):
    """c_ij = log(row_max) - log|a_ij|, +inf for (structural) zeros."""
    absval = np.abs(a.data)
    seg = np.repeat(np.arange(a.n), np.diff(a.indptr))
    row_max = np.zeros(a.n)
    np.maximum.at(row_max, seg, absval)
    with np.errstate(divide="ignore"):
        logv = np.where(absval > 0.0, np.log(absval), -_BIG)
        logmax = np.where(row_max > 0.0, np.log(row_max), 0.0)
    return logmax[seg] - logv, logmax


def max_weight_matching(a: CSR) -> MatchResult:
    """MC64-style maximum product matching with dual-based scaling."""
    n = a.n
    cost, row_logmax = _log_costs(a)

    row_of_col = np.full(n, -1, dtype=np.int64)
    col_of_row = np.full(n, -1, dtype=np.int64)
    u = np.zeros(n)  # row duals
    v = np.zeros(n)  # col duals

    # --- greedy pass: claim the cheapest (cost==0 → max-abs) entry per row
    for i in range(n):
        s, e = a.indptr[i], a.indptr[i + 1]
        if s == e:
            continue
        local = np.argmin(cost[s:e])
        j = a.indices[s + local]
        if row_of_col[j] < 0 and cost[s + local] < _BIG / 2:
            col_of_row[i] = j
            row_of_col[j] = i
            u[i] = cost[s + local]  # u_i + v_j == c_ij with v_j = 0

    # --- shortest augmenting path (Dijkstra with potentials) for the rest
    for i0 in range(n):
        if col_of_row[i0] >= 0:
            continue
        dist = np.full(n, np.inf)     # tentative distance per column
        pred_row = np.full(n, -1, dtype=np.int64)
        visited_cols = []
        heap = []
        # relax edges out of i0
        s, e = a.indptr[i0], a.indptr[i0 + 1]
        for t in range(s, e):
            j = int(a.indices[t])
            d = cost[t] - u[i0] - v[j]
            if d < dist[j]:
                dist[j] = d
                pred_row[j] = i0
                heapq.heappush(heap, (d, j))
        final = np.zeros(n, dtype=bool)
        j_end = -1
        while heap:
            d, j = heapq.heappop(heap)
            if final[j] or d > dist[j]:
                continue
            final[j] = True
            visited_cols.append(j)
            if row_of_col[j] < 0:
                j_end = j
                break
            i = int(row_of_col[j])
            s, e = a.indptr[i], a.indptr[i + 1]
            for t in range(s, e):
                j2 = int(a.indices[t])
                if final[j2]:
                    continue
                d2 = d + (cost[t] - u[i] - v[j2])
                if d2 < dist[j2] - 1e-300:
                    dist[j2] = d2
                    pred_row[j2] = i
                    heapq.heappush(heap, (d2, j2))
        if j_end < 0:
            continue  # structurally singular row; leave unmatched
        # update duals (standard JV update)
        d_end = dist[j_end]
        for j in visited_cols:
            if j == j_end:
                continue
            v[j] += dist[j] - d_end
            u[int(row_of_col[j])] -= dist[j] - d_end
        u[i0] += d_end
        # augment along predecessor chain
        j = j_end
        while True:
            i = int(pred_row[j])
            row_of_col[j] = i
            col_of_row[i], j = j, col_of_row[i]
            if j < 0:
                break

    singular = bool(np.any(col_of_row < 0))
    if singular:
        # complete arbitrarily with unused columns so perms stay valid
        free_cols = np.setdiff1d(np.arange(n), col_of_row[col_of_row >= 0])
        col_of_row[col_of_row < 0] = free_cols

    # --- scaling from duals: with invariant c_ij - u_i - v_j >= 0 (== 0 on
    # matched edges) and c_ij = logmax_i - log|a_ij|:
    #   log(r_i s_j |a_ij|) = -(c_ij - u_i - v_j)  for  r_i = e^{u_i - logmax_i},
    #   s_j = e^{v_j}  →  product == 1 on matched edges, <= 1 elsewhere.
    r = np.exp(np.clip(u - row_logmax, -700, 700))
    s = np.exp(np.clip(v, -700, 700))
    # guard: any zero row maxima
    r[~np.isfinite(r)] = 1.0
    s[~np.isfinite(s)] = 1.0
    return MatchResult(col_of_row, r, s, singular)


def apply_static_pivoting(a: CSR, match: MatchResult):
    """Return B = P_match( diag(r) A diag(s) ) with matched entries on the
    diagonal (|diag| == 1 where the matching succeeded), plus the column
    permutation q (B[:, :] = scaled_A[:, q])."""
    scaled = a.scale(match.row_scale, match.col_scale)
    # column permutation: new column k = old column col_of_row[k] would put
    # entry (i, sigma(i)) at (i, i) if we permute columns by sigma:
    q = match.col_of_row.copy()
    b = scaled.permute(np.arange(a.n), q)
    return b, q
