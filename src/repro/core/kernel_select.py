"""HYLU's smart kernel-selection strategy (§2.1/§2.2).

"The number of floating-point operations is calculated during symbolic
factorization, and supernodes are also detected. HYLU will select the
numerical kernel based on these numbers and other information."

Modes (each is a complete execution plan flavor):

  rowrow      — ordinary up-looking, no supernodes at all (KLU-style).
                Best for extremely sparse matrices (circuits): panels of
                width 1, no padding waste, no TRSM/GEMM overhead.
  hybrid      — the paper's default: fundamental supernodes (+light relaxed
                amalgamation) processed with sup-sup TRSM+GEMM, standalone
                rows with row-row/sup-row updates. One data structure.
  supernodal  — aggressive amalgamation, everything forced into supernodes
                (PARDISO/SuperLU-like); used as internal baseline.

The selector mirrors the paper's statistics: symbolic FLOPs per LU nonzero
(arithmetic intensity), supernode coverage and mean width.
"""
from __future__ import annotations

import dataclasses

from .matrix import CSR
from .symbolic import symbolic_factorize, symbolic_stats, Symbolic


@dataclasses.dataclass
class KernelChoice:
    mode: str            # rowrow | hybrid | supernodal
    relax: int
    max_super: int
    stats: dict
    reason: str


# thresholds (tuned on the synthetic suite; same *shape* as NICSLU/HYLU's
# flops/nnz criterion)
FLOPS_PER_NNZ_ROWROW = 40.0     # below → matrix is circuit-like (NICSLU-style criterion)
COVERAGE_ROWROW = 0.15          # almost no supernode structure
COVERAGE_DENSE = 0.60
FLOPS_PER_NNZ_DENSE = 150.0


def select_kernel(pat_sym: CSR, force_mode: str | None = None,
                  relax: int = 8, max_super: int = 128) -> tuple[KernelChoice, Symbolic]:
    """Run symbolic analysis, compute statistics, pick the kernel mode.

    Returns the choice and the symbolic analysis matching it (rowrow mode
    re-runs symbolic with supernodes disabled so the plan has width-1 nodes).
    """
    sym = symbolic_factorize(pat_sym, relax=relax, max_super=max_super)
    st = symbolic_stats(sym)

    if force_mode is not None:
        mode = force_mode
        reason = "forced"
    elif (st["flops_per_nnz"] < FLOPS_PER_NNZ_ROWROW
            or st["supernode_coverage"] < COVERAGE_ROWROW):
        mode = "rowrow"
        reason = (f"flops/nnz={st['flops_per_nnz']:.1f} "
                  f"coverage={st['supernode_coverage']:.2f} → row-row kernel")
    elif (st["supernode_coverage"] > COVERAGE_DENSE
            and st["flops_per_nnz"] > FLOPS_PER_NNZ_DENSE):
        mode = "hybrid"   # still hybrid: standalone rows keep row kernels
        reason = (f"dense-ish (coverage={st['supernode_coverage']:.2f}) → "
                  f"hybrid with wide supernodes")
    else:
        mode = "hybrid"
        reason = (f"flops/nnz={st['flops_per_nnz']:.1f} "
                  f"coverage={st['supernode_coverage']:.2f} → hybrid kernels")

    if mode == "rowrow":
        sym = symbolic_factorize(pat_sym, relax=0, max_super=1,
                                 do_supernodes=False)
    elif mode == "supernodal":
        sym = symbolic_factorize(pat_sym, relax=max(relax, 16),
                                 max_super=max_super)
    return KernelChoice(mode=mode, relax=relax, max_super=max_super,
                        stats=st, reason=reason), sym
