"""Content-addressed plan cache: Analysis artifacts by pattern fingerprint.

HYLU's analyze phase (matching + ordering + symbolic + plan build) is pure
host work and, for the serving regime, a per-pattern tax that should be
paid **once per pattern, ever** — not once per process.  This module makes
the analysis a cached, persisted, shared artifact:

* ``PlanCache`` — an LRU map ``plan_fingerprint → Analysis`` (the
  fingerprint hashes n, indptr/indices and every plan/engine-affecting
  option; see :mod:`repro.core.options`).  A cached ``Analysis`` carries
  its per-pattern compiled-engine cache (``jit_cache``), so a warm hit
  also reuses every already-compiled XLA program.
* disk persistence — ``save_analysis`` / ``load_analysis`` serialize the
  full analysis artifact (matching, ordering, symbolic structure, the
  static FactorPlan with its node/edge maps) to a single versioned ``.npz``
  under ``<cache root>/plan_cache/<fingerprint>.npz``, where the cache
  root is ``HyluOptions.cache_root`` / ``$HYLU_CACHE_ROOT`` / the repo's
  ``checkpoints`` dir (see :func:`default_cache_root` — never the CWD,
  so bench and CI runs don't scatter cache dirs).  A fresh process
  loads the artifact and skips the host analyze phase entirely; only the
  XLA compile remains, which the persistent jax compilation cache absorbs.
  The level-bucketed factor schedule and solve structure are *derived*
  deterministically from the persisted plan at first engine build, so a
  reloaded analysis produces bit-identical factors and solves.

Persistence format (``FORMAT_VERSION``): one ``.npz`` holding a JSON
``meta`` record (version, fingerprint, options key, scalar fields) plus
flat numpy arrays — ragged plan structures (per-node patterns, per-node
edge lists, per-edge col_maps) are stored as concatenated arrays with
``*_ptr`` offset vectors, CSR-style.  Unknown versions and fingerprint
mismatches raise ``PlanCacheFormatError`` (a ``ValueError``); the cache
treats such files as misses and re-analyzes rather than guessing.

Cache-semantics note: the fingerprint is content-addressed on the
*pattern*, not the values.  A warm hit reuses matching/scaling computed
from the values that first populated the entry — exactly the repeated-
solve discipline of ``solve_sequence`` (static pivoting + perturbation +
refinement absorb mild value drift).  Callers whose values drift far
enough to need fresh pivoting should ``invalidate()`` the pattern.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
import zipfile
from collections import OrderedDict

import numpy as np

from .matrix import CSR
from .matching import MatchResult
from .kernel_select import KernelChoice
from .symbolic import Symbolic
from .plan import FactorPlan, NodePlan, Edge
from .options import HyluOptions, plan_options_key, plan_fingerprint
from .analysis import Analysis, analyze

FORMAT_VERSION = 1
# Sentinel: resolved to <cache root>/plan_cache at PlanCache construction
# (NOT at import), so $HYLU_CACHE_ROOT set after import still wins.
DEFAULT_CACHE_DIR = "auto"


def default_cache_root() -> str:
    """The artifact-store root every component that persists state shares
    (plan cache, corpus downloads): ``$HYLU_CACHE_ROOT`` when set, else
    ``<repo>/checkpoints`` when this package runs from a source checkout
    (the historical location — next to the repo, NOT the CWD), else
    ``~/.cache/hylu`` for installed packages."""
    env = os.environ.get("HYLU_CACHE_ROOT")
    if env:
        return env
    # src/repro/core/plan_cache.py -> repo root is 4 levels up
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    if os.path.exists(os.path.join(repo, "pyproject.toml")):
        return os.path.join(repo, "checkpoints")
    return os.path.join(os.path.expanduser("~"), ".cache", "hylu")


def resolve_cache_dir(directory: str | None,
                      cache_root: str | None = None) -> str | None:
    """Map a PlanCache ``directory`` setting to a concrete path: the
    ``DEFAULT_CACHE_DIR`` sentinel becomes ``<root>/plan_cache`` where
    ``root`` is ``cache_root`` (``HyluOptions.cache_root``) or
    :func:`default_cache_root`; explicit paths and None pass through."""
    if directory != DEFAULT_CACHE_DIR:
        return directory
    return os.path.join(cache_root or default_cache_root(), "plan_cache")


class PlanCacheFormatError(ValueError):
    """Raised when a persisted plan artifact cannot be trusted: unknown
    format version, fingerprint mismatch, or a malformed file."""


def _jsonable(v):
    if isinstance(v, dict):
        return {k: _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.bool_):
        return bool(v)
    return v


def _cat(arrs, dtype=np.int64):
    """Concatenate possibly-empty ragged pieces with a stable dtype."""
    arrs = [np.asarray(a, dtype=dtype) for a in arrs]
    return (np.concatenate(arrs) if arrs
            else np.empty(0, dtype=dtype))


def _ptr(lengths):
    out = np.zeros(len(lengths) + 1, dtype=np.int64)
    np.cumsum(lengths, out=out[1:])
    return out


def save_analysis(an: Analysis, path: str) -> str:
    """Serialize one Analysis to a versioned ``.npz`` artifact (atomic
    write).  Everything value-independent about the pattern is captured;
    the compiled-engine cache is not (XLA programs persist via the jax
    compilation cache instead)."""
    plan, sym, match = an.plan, an.sym, an.match
    nodes = plan.nodes
    meta = {
        "format_version": FORMAT_VERSION,
        "fingerprint": an.fingerprint,
        "pattern_key": an.pattern_key,
        "options_key": repr(plan_options_key(an.opts)),
        "n": int(an.n),
        "ordering_name": an.ordering_name,
        "match_structurally_singular": bool(match.structurally_singular),
        "choice": {"mode": an.choice.mode, "relax": int(an.choice.relax),
                   "max_super": int(an.choice.max_super),
                   "reason": an.choice.reason,
                   "stats": _jsonable(an.choice.stats)},
        "sym": {"flops": float(sym.flops), "nnz_l": int(sym.nnz_l)},
        "plan": {"total_slots": int(plan.total_slots), "mode": plan.mode,
                 "useful_flops": float(plan.useful_flops),
                 "padded_flops": float(plan.padded_flops),
                 "n_bulk_levels": int(plan.n_bulk_levels)},
        "timings": _jsonable(an.timings),
    }
    edge_lists = [nd.edges for nd in nodes]
    all_edges = [e for edges in edge_lists for e in edges]
    arrays = dict(
        match_col_of_row=match.col_of_row,
        match_row_scale=match.row_scale,
        match_col_scale=match.col_scale,
        q=an.q, p=an.p,
        src_map=an.src_map, scale_map=an.scale_map,
        m_indptr=an.m_pattern[0], m_indices=an.m_pattern[1],
        sym_parent=sym.parent,
        sym_lrow_ptr=sym.lrow_ptr, sym_lrow_idx=sym.lrow_idx,
        sym_lcol_ptr=sym.lcol_ptr, sym_lcol_idx=sym.lcol_idx,
        sym_cc=sym.cc, sym_row_flops=sym.row_flops,
        sym_snode_of=sym.snode_of,
        sym_snode_start=sym.snode_start, sym_snode_end=sym.snode_end,
        plan_panel_offset=plan.panel_offset,
        plan_a_scatter=plan.a_scatter,
        plan_row_perm_slots=plan.row_perm_slots,
        node_r0=np.array([nd.r0 for nd in nodes], dtype=np.int64),
        node_r1=np.array([nd.r1 for nd in nodes], dtype=np.int64),
        node_level=np.array([nd.level for nd in nodes], dtype=np.int64),
        node_lsize=np.array([nd.lsize for nd in nodes], dtype=np.int64),
        node_usize=np.array([nd.usize for nd in nodes], dtype=np.int64),
        node_pat_ptr=_ptr([len(nd.pattern) for nd in nodes]),
        node_pat=_cat([nd.pattern for nd in nodes]),
        edge_ptr=_ptr([len(edges) for edges in edge_lists]),
        edge_src=np.array([e.src for e in all_edges], dtype=np.int64),
        edge_cm_ptr=_ptr([len(e.col_map) for e in all_edges]),
        edge_cm=_cat([e.col_map for e in all_edges]),
    )
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            np.savez_compressed(f, meta=json.dumps(meta), **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def load_analysis(path: str, opts: HyluOptions | None = None,
                  expected_fingerprint: str | None = None) -> Analysis:
    """Reconstruct an Analysis from a persisted artifact.

    ``opts`` becomes the loaded analysis' options and must agree with the
    artifact on every plan-affecting field (validated via the persisted
    options key).  ``expected_fingerprint`` additionally pins the artifact
    to a specific content address.  Raises ``PlanCacheFormatError`` when
    the artifact cannot be trusted."""
    opts = opts or HyluOptions()
    t0 = time.perf_counter()
    try:
        z = np.load(path, allow_pickle=False)
        meta = json.loads(str(z["meta"][()]))
    except (OSError, KeyError, ValueError, EOFError,
            zipfile.BadZipFile) as e:
        raise PlanCacheFormatError(f"unreadable plan artifact {path}: {e}")
    if meta.get("format_version") != FORMAT_VERSION:
        raise PlanCacheFormatError(
            f"{path}: format version {meta.get('format_version')!r} != "
            f"supported {FORMAT_VERSION}")
    if (expected_fingerprint is not None
            and meta.get("fingerprint") != expected_fingerprint):
        raise PlanCacheFormatError(
            f"{path}: stored fingerprint {meta.get('fingerprint')!r} does "
            f"not match expected {expected_fingerprint!r}")
    if meta.get("options_key") != repr(plan_options_key(opts)):
        raise PlanCacheFormatError(
            f"{path}: artifact was analyzed under plan options "
            f"{meta.get('options_key')} but is being loaded with "
            f"{plan_options_key(opts)!r}")
    required = {
        "match_col_of_row", "match_row_scale", "match_col_scale", "q", "p",
        "src_map", "scale_map", "m_indptr", "m_indices", "sym_parent",
        "sym_lrow_ptr", "sym_lrow_idx", "sym_lcol_ptr", "sym_lcol_idx",
        "sym_cc", "sym_row_flops", "sym_snode_of", "sym_snode_start",
        "sym_snode_end", "plan_panel_offset", "plan_a_scatter",
        "plan_row_perm_slots", "node_r0", "node_r1", "node_level",
        "node_lsize", "node_usize", "node_pat_ptr", "node_pat",
        "edge_ptr", "edge_src", "edge_cm_ptr", "edge_cm"}
    missing = required.difference(z.files)
    if missing:
        raise PlanCacheFormatError(
            f"{path}: artifact is missing arrays {sorted(missing)}")

    n = int(meta["n"])
    match = MatchResult(
        col_of_row=z["match_col_of_row"], row_scale=z["match_row_scale"],
        col_scale=z["match_col_scale"],
        structurally_singular=bool(meta["match_structurally_singular"]))
    cm = meta["choice"]
    choice = KernelChoice(mode=cm["mode"], relax=cm["relax"],
                          max_super=cm["max_super"], stats=cm["stats"],
                          reason=cm["reason"])
    sym = Symbolic(
        n=n, parent=z["sym_parent"],
        lrow_ptr=z["sym_lrow_ptr"], lrow_idx=z["sym_lrow_idx"],
        lcol_ptr=z["sym_lcol_ptr"], lcol_idx=z["sym_lcol_idx"],
        cc=z["sym_cc"], flops=float(meta["sym"]["flops"]),
        row_flops=z["sym_row_flops"], snode_of=z["sym_snode_of"],
        snode_start=z["sym_snode_start"], snode_end=z["sym_snode_end"],
        nnz_l=int(meta["sym"]["nnz_l"]))

    node_r0, node_r1 = z["node_r0"], z["node_r1"]
    node_level = z["node_level"]
    node_lsize, node_usize = z["node_lsize"], z["node_usize"]
    pat_ptr, pat = z["node_pat_ptr"], z["node_pat"]
    edge_ptr, edge_src = z["edge_ptr"], z["edge_src"]
    cm_ptr, cm_cat = z["edge_cm_ptr"], z["edge_cm"]
    nodes = []
    for t in range(len(node_r0)):
        edges = []
        for j in range(int(edge_ptr[t]), int(edge_ptr[t + 1])):
            edges.append(Edge(
                src=int(edge_src[j]),
                col_map=cm_cat[int(cm_ptr[j]):int(cm_ptr[j + 1])]))
        nodes.append(NodePlan(
            nid=t, r0=int(node_r0[t]), r1=int(node_r1[t]),
            pattern=pat[int(pat_ptr[t]):int(pat_ptr[t + 1])],
            lsize=int(node_lsize[t]), usize=int(node_usize[t]),
            edges=edges, level=int(node_level[t])))
    n_levels = int(node_level.max()) + 1 if len(node_level) else 0
    levels = [np.where(node_level == lv)[0] for lv in range(n_levels)]
    pm = meta["plan"]
    plan = FactorPlan(
        n=n, nodes=nodes, panel_offset=z["plan_panel_offset"],
        total_slots=int(pm["total_slots"]), a_scatter=z["plan_a_scatter"],
        levels=levels, n_bulk_levels=int(pm["n_bulk_levels"]),
        mode=pm["mode"], useful_flops=float(pm["useful_flops"]),
        padded_flops=float(pm["padded_flops"]),
        row_perm_slots=z["plan_row_perm_slots"])

    load_s = time.perf_counter() - t0
    timings = {"load": load_s, "total": load_s,
               "analyzed_total": float(meta["timings"].get("total", 0.0))}
    return Analysis(
        n=n, opts=opts, match=match, q=z["q"], p=z["p"],
        ordering_name=meta["ordering_name"], choice=choice, sym=sym,
        plan=plan, src_map=z["src_map"], scale_map=z["scale_map"],
        m_pattern=(z["m_indptr"], z["m_indices"]), timings=timings,
        pattern_key=meta["pattern_key"], fingerprint=meta["fingerprint"])


@dataclasses.dataclass
class PlanCache:
    """LRU plan cache with optional disk persistence.

    capacity   — max in-memory entries; least-recently-used analyses (and
                 their compiled engines) are evicted beyond it
    directory  — persistence root (``<directory>/<fingerprint>.npz``);
                 None disables disk entirely; the default ``"auto"``
                 sentinel resolves to ``<cache root>/plan_cache`` at
                 construction via :func:`resolve_cache_dir` — i.e.
                 ``$HYLU_CACHE_ROOT`` or next to the repo, never the CWD
    cache_root — overrides the auto-resolved root (``HyluOptions.
                 cache_root``); ignored when ``directory`` is explicit

    ``stats`` counters: ``hits`` (in-memory), ``disk_hits`` (loaded from
    the artifact store — the analyze phase was skipped), ``misses`` (full
    host analyze ran; equals ``analyze_calls``), ``saves``, ``evictions``,
    plus accumulated ``analyze_s`` / ``load_s`` wall times."""
    capacity: int = 32
    directory: str | None = DEFAULT_CACHE_DIR
    cache_root: str | None = None

    def __post_init__(self):
        self.directory = resolve_cache_dir(self.directory, self.cache_root)
        self._entries: OrderedDict[str, Analysis] = OrderedDict()
        self.stats = dict(hits=0, misses=0, disk_hits=0, saves=0,
                          evictions=0, analyze_calls=0,
                          analyze_s=0.0, load_s=0.0)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, fingerprint: str) -> bool:
        return fingerprint in self._entries

    def fingerprints(self):
        return list(self._entries)

    def path_for(self, fingerprint: str) -> str | None:
        if self.directory is None:
            return None
        return os.path.join(self.directory, f"{fingerprint}.npz")

    def fingerprint(self, a_or_pattern, opts: HyluOptions | None = None) -> str:
        return plan_fingerprint(a_or_pattern, opts)

    def get_or_analyze(self, a: CSR, opts: HyluOptions | None = None,
                       fingerprint: str | None = None) -> Analysis:
        """The cache's main entry: the Analysis for ``a``'s pattern under
        ``opts``, from memory, from the artifact store, or by running
        ``analyze`` (cold; the result is persisted when a directory is
        configured).  Warm hits ignore ``a``'s values (see the module
        docstring's cache-semantics note).  ``fingerprint`` passes an
        already-computed ``plan_fingerprint(a, opts)`` so hot callers (the
        serving dispatcher groups by it anyway) skip re-hashing the
        O(nnz) pattern."""
        opts = opts or HyluOptions()
        fp = fingerprint or plan_fingerprint(a, opts)
        an = self._entries.get(fp)
        if an is not None:
            self._entries.move_to_end(fp)
            self.stats["hits"] += 1
            return self._with_opts(an, opts)
        path = self.path_for(fp)
        if path is not None and os.path.exists(path):
            try:
                t0 = time.perf_counter()
                an = load_analysis(path, opts=opts, expected_fingerprint=fp)
                self.stats["load_s"] += time.perf_counter() - t0
                self.stats["disk_hits"] += 1
            except PlanCacheFormatError:
                an = None                     # untrusted artifact: re-analyze
        if an is None:
            t0 = time.perf_counter()
            an = analyze(a, opts)
            self.stats["analyze_s"] += time.perf_counter() - t0
            self.stats["misses"] += 1
            self.stats["analyze_calls"] += 1
            if path is not None:
                save_analysis(an, path)
                self.stats["saves"] += 1
        self._insert(fp, an)
        return an

    def put(self, an: Analysis) -> str:
        """Insert an externally-built Analysis (persisting it when a
        directory is configured) and return its fingerprint."""
        if not an.fingerprint:
            raise ValueError("analysis has no fingerprint (built by an old "
                             "analyze()?) — cannot content-address it")
        path = self.path_for(an.fingerprint)
        if path is not None and not os.path.exists(path):
            save_analysis(an, path)
            self.stats["saves"] += 1
        self._insert(an.fingerprint, an)
        return an.fingerprint

    def invalidate(self, fingerprint: str, disk: bool = False) -> None:
        """Drop one entry (e.g. after heavy value drift made the cached
        matching/scaling stale); ``disk=True`` also removes the artifact."""
        self._entries.pop(fingerprint, None)
        path = self.path_for(fingerprint)
        if disk and path is not None and os.path.exists(path):
            os.remove(path)

    def clear(self) -> None:
        self._entries.clear()

    @staticmethod
    def _with_opts(an: Analysis, opts: HyluOptions) -> Analysis:
        """A hit must honor the *caller's* runtime-only options (engine /
        mesh / donate / refinement caps — the fields the fingerprint
        deliberately excludes), not whichever opts first populated the
        entry.  When they differ, return a shallow per-caller view: same
        plan/symbolic/matching arrays AND the same ``jit_cache`` dict
        (compiled engines stay shared — its keys already encode
        dtype/pallas/schedule/mesh), only ``opts`` rebound.  This keeps
        memory hits consistent with the disk-hit path, which loads the
        artifact under the caller's opts."""
        if an.opts == opts:
            return an
        return dataclasses.replace(an, opts=opts)

    def _insert(self, fp: str, an: Analysis) -> None:
        self._entries[fp] = an
        self._entries.move_to_end(fp)
        while len(self._entries) > max(int(self.capacity), 1):
            self._entries.popitem(last=False)
            self.stats["evictions"] += 1
