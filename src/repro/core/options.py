"""Solver options, mesh resolution, and pattern fingerprints.

This is the bottom layer of the core stack (options → analysis → batched →
api facade): it depends on nothing but numpy and is imported by every other
core module, so the option schema and the content-address of a plan live in
exactly one place.

Fingerprints are the content address of the plan cache
(:mod:`repro.core.plan_cache`) and of the serving dispatcher
(:mod:`repro.serve.solver_service`):

    pattern_key(n, indptr, indices)        — the sparsity pattern alone
    plan_fingerprint(pattern, opts)        — pattern + every option that
                                             changes the analysis artifact or
                                             the compiled engine

Two analyses share a fingerprint iff they produce interchangeable plans AND
interchangeable compiled programs.  Runtime-only knobs (``engine``,
``mesh``, ``donate``, ``refine_max_iter``, ``refine_tol``) are deliberately
NOT part of the fingerprint: they select how a cached plan is *executed*,
not what is computed at analysis time (the per-analysis jit cache already
keys engines on dtype/pallas/schedule/mesh).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass
class HyluOptions:
    """Solver options — every knob of the analyze/factor/solve pipeline.
    Field-by-field documentation lives in docs/API.md (kept in sync by the
    docs-lint CI step)."""
    force_mode: str | None = None          # rowrow | hybrid | supernodal
    orderings: tuple = ("min_degree", "nested_dissection", "natural")
    relax: int = 8
    max_super: int = 128
    amalg_fill_tol: float = 0.0            # post-symbolic supernode
                                           # amalgamation: merge adjacent
                                           # nodes while the extra explicit
                                           # zeros stay under this fraction
                                           # of their separate storage
                                           # (0 = off, plan unchanged)
    perturb_eps: float = 1e-8
    refine_max_iter: int = 3
    refine_tol: float = 1e-12
    bulk_min_width: int = 8
    engine: str = "ref"                    # ref | jax — default numeric engine
    use_pallas: bool = False               # route jax panel updates via Pallas
    factor_schedule: str = "bucketed"      # bucketed (O(levels) trace) |
                                           # unrolled (O(nodes+edges) oracle)
    mesh: object = None                    # shard the batched path over the
                                           # system-batch axis K: None (single
                                           # device) | int (first N devices,
                                           # launch.mesh.make_solver_mesh) |
                                           # a 1-D jax.sharding.Mesh
    donate: bool = False                   # sequence pipeline donates value/
                                           # RHS/factor buffers step-to-step
                                           # (consumed states; no realloc)
    cache_root: str | None = None          # artifact-store root for plan
                                           # cache/corpus downloads; None →
                                           # $HYLU_CACHE_ROOT or
                                           # <repo>/checkpoints (runtime-only,
                                           # never part of the fingerprint)


# Options that change the analysis artifact (ordering/symbolic/plan) or the
# compiled engine built from it — the option half of a plan fingerprint.
PLAN_OPTION_FIELDS = ("force_mode", "orderings", "relax", "max_super",
                      "amalg_fill_tol", "perturb_eps", "bulk_min_width",
                      "factor_schedule", "use_pallas")


def plan_options_key(opts: HyluOptions | None) -> tuple:
    """Hashable tuple of the plan/engine-affecting option fields (see
    ``PLAN_OPTION_FIELDS``) — equal keys ⇒ interchangeable plans+engines."""
    opts = opts or HyluOptions()
    out = []
    for name in PLAN_OPTION_FIELDS:
        v = getattr(opts, name)
        out.append(tuple(v) if isinstance(v, (list, tuple)) else v)
    return tuple(out)


def _pattern_parts(a_or_pattern) -> tuple:
    """(n, indptr, indices) from a CSR-like object or an (indptr, indices)
    pair."""
    if hasattr(a_or_pattern, "indptr"):
        return (int(a_or_pattern.n), a_or_pattern.indptr,
                a_or_pattern.indices)
    indptr, indices = a_or_pattern
    indptr = np.asarray(indptr)
    return len(indptr) - 1, indptr, indices


def pattern_key(a_or_pattern) -> str:
    """Deterministic content hash of a sparsity pattern alone:
    sha256 over (n, indptr, indices).  Value- and option-independent."""
    n, indptr, indices = _pattern_parts(a_or_pattern)
    h = hashlib.sha256(b"hylu-pattern-v1")
    h.update(int(n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(indices, dtype=np.int64).tobytes())
    return h.hexdigest()


def plan_fingerprint(a_or_pattern, opts: HyluOptions | None = None,
                     pkey: str | None = None) -> str:
    """The content address of one analysis artifact: sha256 over the
    pattern key plus ``plan_options_key(opts)``.  This is the key of the
    plan cache and of the serving dispatcher's group-by.  ``pkey`` passes
    an already-computed ``pattern_key`` so callers that have one in hand
    don't re-hash the O(nnz) pattern."""
    h = hashlib.sha256(b"hylu-plan-v1")
    h.update((pattern_key(a_or_pattern) if pkey is None else pkey).encode())
    h.update(repr(plan_options_key(opts)).encode())
    return h.hexdigest()


def _resolve_mesh(mesh):
    """HyluOptions.mesh → a 1-D jax Mesh (or None for the unsharded path):
    None passes through, an int N builds launch.mesh.make_solver_mesh(N),
    a Mesh is validated to one axis."""
    if mesh is None:
        return None
    if isinstance(mesh, (int, np.integer)):
        from repro.launch.mesh import make_solver_mesh
        return make_solver_mesh(int(mesh))
    if not hasattr(mesh, "axis_names"):
        raise TypeError(f"mesh must be None, an int device count, or a "
                        f"jax.sharding.Mesh — got {type(mesh).__name__}")
    if len(mesh.axis_names) != 1:
        raise ValueError("the batched solver shards over one system-batch "
                         f"axis; got a {len(mesh.axis_names)}-D mesh "
                         f"{mesh.axis_names}")
    return mesh


def _mesh_cache_key(mesh):
    """Hashable identity of a resolved mesh for the per-analysis jit cache:
    same devices + axis name ⇒ same compiled programs."""
    if mesh is None:
        return None
    return (mesh.axis_names[0],
            tuple(d.id for d in mesh.devices.flat))
