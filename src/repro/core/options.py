"""Solver options, mesh resolution, and pattern fingerprints.

This is the bottom layer of the core stack (options → analysis → batched →
api facade): it depends on nothing but numpy and is imported by every other
core module, so the option schema and the content-address of a plan live in
exactly one place.

Fingerprints are the content address of the plan cache
(:mod:`repro.core.plan_cache`) and of the serving dispatcher
(:mod:`repro.serve.solver_service`):

    pattern_key(n, indptr, indices)        — the sparsity pattern alone
    plan_fingerprint(pattern, opts)        — pattern + every option that
                                             changes the analysis artifact or
                                             the compiled engine

Two analyses share a fingerprint iff they produce interchangeable plans AND
interchangeable compiled programs.  Runtime-only knobs (``engine``,
``mesh``, ``donate``, ``refine_max_iter``, ``refine_tol``, ``refine_dtype``,
``fp64_fallback``) are deliberately NOT part of the fingerprint: they select
how a cached plan is *executed*, not what is computed at analysis time (the
per-analysis jit cache already keys engines on dtype/pallas/schedule/mesh).

Mixed precision: ``factor_dtype`` picks the precision of the factor panels
and the substitution (fp32 halves the bandwidth of the batched-refactor hot
path); ``refine_dtype`` picks the precision the residual and the solution
are accumulated in (``"auto"`` → fp64 whenever x64 is enabled).  The
``perturb_eps``/``refine_tol`` defaults are ``None`` sentinels resolved
against the relevant dtype's machine epsilon — the historical fp64 literals
``1e-8``/``1e-12`` fall out exactly for ``factor_dtype="float64"``, and
explicit values are always honored verbatim.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np


@dataclasses.dataclass
class HyluOptions:
    """Solver options — every knob of the analyze/factor/solve pipeline.
    Field-by-field documentation lives in docs/API.md (kept in sync by the
    docs-lint CI step)."""
    force_mode: str | None = None          # rowrow | hybrid | supernodal
    orderings: tuple = ("min_degree", "nested_dissection", "natural")
    relax: int = 8
    max_super: int = 128
    amalg_fill_tol: float = 0.0            # post-symbolic supernode
                                           # amalgamation: merge adjacent
                                           # nodes while the extra explicit
                                           # zeros stay under this fraction
                                           # of their separate storage
                                           # (0 = off, plan unchanged)
    perturb_eps: float | None = None       # pivot-perturbation threshold as a
                                           # fraction of max|M|; None → 1e-8
                                           # scaled by sqrt(eps(factor_dtype)
                                           # / eps(float64)) — exactly 1e-8
                                           # for float64
    refine_max_iter: int = 3
    refine_tol: float | None = None        # refinement residual target; None
                                           # → 1e-12 scaled by
                                           # eps(refine_dtype)/eps(float64) —
                                           # exactly 1e-12 for float64
    factor_dtype: str = "float64"          # precision of the factor panels +
                                           # substitution: float64 | float32 |
                                           # bfloat16 (experimental)
    refine_dtype: str = "auto"             # precision of residual/solution
                                           # accumulation in refinement and of
                                           # staged A-value/RHS batches:
                                           # auto → float64 when x64 is on
                                           # (else factor_dtype) | an explicit
                                           # dtype name (runtime-only)
    fp64_fallback: bool = True             # batched solve: re-factor+re-solve
                                           # the refinement-failed subset in
                                           # float64 (reduced-precision
                                           # engines only; runtime-only)
    deadline_ms: float | None = None       # serving: default per-request
                                           # latency budget for the async
                                           # server's deadline-based flush;
                                           # None = no deadline
                                           # (runtime-only)
    retry_max: int = 1                     # serving escalation ladder: how
                                           # many perturbed re-factor retries
                                           # a refinement-failed request gets
                                           # after the fp64 fallback, before
                                           # it is quarantined (runtime-only)
    retry_perturb_boost: float = 1e4       # multiplier applied to the
                                           # resolved perturb_eps per retry
                                           # attempt (runtime-only)
    bulk_min_width: int = 8
    engine: str = "ref"                    # ref | jax — default numeric engine
    use_pallas: bool = False               # route jax panel updates via Pallas
    factor_schedule: str = "bucketed"      # bucketed (O(levels) trace) |
                                           # unrolled (O(nodes+edges) oracle)
    mesh: object = None                    # shard the batched path over the
                                           # system-batch axis K: None (single
                                           # device) | int (first N devices,
                                           # launch.mesh.make_solver_mesh) |
                                           # a 1-D jax.sharding.Mesh
    donate: bool = False                   # sequence pipeline donates value/
                                           # RHS/factor buffers step-to-step
                                           # (consumed states; no realloc)
    cache_root: str | None = None          # artifact-store root for plan
                                           # cache/corpus downloads; None →
                                           # $HYLU_CACHE_ROOT or
                                           # <repo>/checkpoints (runtime-only,
                                           # never part of the fingerprint)


# Options that change the analysis artifact (ordering/symbolic/plan) or the
# compiled engine built from it — the option half of a plan fingerprint.
PLAN_OPTION_FIELDS = ("force_mode", "orderings", "relax", "max_super",
                      "amalg_fill_tol", "perturb_eps", "bulk_min_width",
                      "factor_schedule", "use_pallas", "factor_dtype")


# Machine epsilons of the supported factor/refine dtypes, kept as a literal
# table so this module stays numpy-only (np.finfo rejects the ml_dtypes
# bfloat16 class on some numpy versions).
_DTYPE_EPS = {
    "float64": 2.220446049250313e-16,
    "float32": 1.1920928955078125e-07,
    "bfloat16": 0.0078125,
}


def dtype_name(dtype) -> str:
    """Canonical name ("float64"/"float32"/"bfloat16") of a dtype given as a
    string, a numpy/jax dtype, or a scalar type."""
    name = dtype if isinstance(dtype, str) else np.dtype(dtype).name
    if name not in _DTYPE_EPS:
        raise ValueError(f"unsupported factor/refine dtype {name!r}: "
                         f"expected one of {sorted(_DTYPE_EPS)}")
    return name


def np_dtype(dtype) -> np.dtype:
    """numpy dtype for a supported dtype name (bfloat16 via ml_dtypes)."""
    name = dtype_name(dtype)
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def resolve_perturb_eps(opts: HyluOptions | None, dtype=None) -> float:
    """The effective pivot-perturbation threshold: an explicit
    ``opts.perturb_eps`` verbatim, else the fp64 literal ``1e-8`` scaled by
    ``sqrt(eps(dtype)/eps(float64))`` (backward-error of LU grows with the
    factor dtype's eps; sqrt keeps the perturbation below the error it
    guards against).  Exactly ``1e-8`` for float64."""
    opts = opts or HyluOptions()
    if opts.perturb_eps is not None:
        return float(opts.perturb_eps)
    name = dtype_name(opts.factor_dtype if dtype is None else dtype)
    return 1e-8 * (_DTYPE_EPS[name] / _DTYPE_EPS["float64"]) ** 0.5


def resolve_refine_tol(opts: HyluOptions | None, dtype=None) -> float:
    """The effective refinement residual target: an explicit
    ``opts.refine_tol`` verbatim, else the fp64 literal ``1e-12`` scaled by
    ``eps(dtype)/eps(float64)`` where ``dtype`` is the precision the
    residual is *computed* in (the refine dtype).  Exactly ``1e-12`` for
    float64 — so the default mixed fp32-factor/fp64-refine path is held to
    the same fp64-quality target as a pure fp64 solve."""
    opts = opts or HyluOptions()
    if opts.refine_tol is not None:
        return float(opts.refine_tol)
    name = dtype_name(opts.factor_dtype if dtype is None else dtype)
    return 1e-12 * (_DTYPE_EPS[name] / _DTYPE_EPS["float64"])


def resolve_retry_perturb(opts: HyluOptions | None, attempt: int,
                          dtype=None) -> float:
    """The pivot-perturbation threshold for retry ``attempt`` (1-based) of
    the serving escalation ladder: the resolved base threshold
    (:func:`resolve_perturb_eps`) boosted by ``retry_perturb_boost`` per
    attempt.  A boosted threshold is an *explicit* ``perturb_eps``, so it
    lands in a distinct plan fingerprint — retries factor through their own
    cached plans and never perturb the healthy traffic's engines."""
    opts = opts or HyluOptions()
    if attempt < 1:
        raise ValueError(f"retry attempt is 1-based, got {attempt}")
    return (resolve_perturb_eps(opts, dtype)
            * float(opts.retry_perturb_boost) ** attempt)


def resolve_dtype_names(opts: HyluOptions | None,
                        x64_enabled: bool = True) -> tuple:
    """(factor, refine) dtype names under the given x64 availability:
    ``refine_dtype="auto"`` resolves to float64 whenever x64 is enabled,
    else to the factor dtype (a pure reduced-precision engine)."""
    opts = opts or HyluOptions()
    f = dtype_name(opts.factor_dtype)
    r = opts.refine_dtype
    if r in (None, "auto"):
        r = "float64" if x64_enabled else f
    return f, dtype_name(r)


def plan_options_key(opts: HyluOptions | None) -> tuple:
    """Hashable tuple of the plan/engine-affecting option fields (see
    ``PLAN_OPTION_FIELDS``) — equal keys ⇒ interchangeable plans+engines.
    ``perturb_eps`` enters resolved against the factor dtype, so the
    ``None`` default and the equivalent explicit literal fingerprint the
    same."""
    opts = opts or HyluOptions()
    out = []
    for name in PLAN_OPTION_FIELDS:
        if name == "perturb_eps":
            out.append(resolve_perturb_eps(opts))
            continue
        v = getattr(opts, name)
        out.append(tuple(v) if isinstance(v, (list, tuple)) else v)
    return tuple(out)


def _pattern_parts(a_or_pattern) -> tuple:
    """(n, indptr, indices) from a CSR-like object or an (indptr, indices)
    pair."""
    if hasattr(a_or_pattern, "indptr"):
        return (int(a_or_pattern.n), a_or_pattern.indptr,
                a_or_pattern.indices)
    indptr, indices = a_or_pattern
    indptr = np.asarray(indptr)
    return len(indptr) - 1, indptr, indices


def pattern_key(a_or_pattern) -> str:
    """Deterministic content hash of a sparsity pattern alone:
    sha256 over (n, indptr, indices).  Value- and option-independent."""
    n, indptr, indices = _pattern_parts(a_or_pattern)
    h = hashlib.sha256(b"hylu-pattern-v1")
    h.update(int(n).to_bytes(8, "little"))
    h.update(np.ascontiguousarray(indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(indices, dtype=np.int64).tobytes())
    return h.hexdigest()


def plan_fingerprint(a_or_pattern, opts: HyluOptions | None = None,
                     pkey: str | None = None) -> str:
    """The content address of one analysis artifact: sha256 over the
    pattern key plus ``plan_options_key(opts)``.  This is the key of the
    plan cache and of the serving dispatcher's group-by.  ``pkey`` passes
    an already-computed ``pattern_key`` so callers that have one in hand
    don't re-hash the O(nnz) pattern."""
    h = hashlib.sha256(b"hylu-plan-v1")
    h.update((pattern_key(a_or_pattern) if pkey is None else pkey).encode())
    h.update(repr(plan_options_key(opts)).encode())
    return h.hexdigest()


def _resolve_mesh(mesh):
    """HyluOptions.mesh → a 1-D jax Mesh (or None for the unsharded path):
    None passes through, an int N builds launch.mesh.make_solver_mesh(N),
    a Mesh is validated to one axis."""
    if mesh is None:
        return None
    if isinstance(mesh, (int, np.integer)):
        from repro.launch.mesh import make_solver_mesh
        return make_solver_mesh(int(mesh))
    if not hasattr(mesh, "axis_names"):
        raise TypeError(f"mesh must be None, an int device count, or a "
                        f"jax.sharding.Mesh — got {type(mesh).__name__}")
    if len(mesh.axis_names) != 1:
        raise ValueError("the batched solver shards over one system-batch "
                         f"axis; got a {len(mesh.axis_names)}-D mesh "
                         f"{mesh.axis_names}")
    return mesh


def _mesh_cache_key(mesh):
    """Hashable identity of a resolved mesh for the per-analysis jit cache:
    same devices + axis name ⇒ same compiled programs."""
    if mesh is None:
        return None
    return (mesh.axis_names[0],
            tuple(d.id for d in mesh.devices.flat))
