"""Static factorization plan (the TPU-native data structure of this port).

HYLU's data structure is "elaborated to support the hybrid numerical kernels
in a common way" (§2.2).  On TPU the analogue is a *static execution plan*
computed once at analysis time:

  - every node (supernode or standalone row) owns a dense panel
    ``nr × |P_T|`` where ``P_T`` is the sorted union column pattern of the
    node's rows: [ L-part cols < r0 | diagonal block r0..r1 | U-part cols > r1 ].
    Panels are zero-initialized; structural zeros inside the union pattern
    carry exact numeric zeros, which makes relaxed supernode amalgamation and
    full-panel updates *numerically exact* (see notes below).
  - every dependency edge S → T carries one small int vector ``col_map``:
    positions of S's (block ∪ U-struct) columns inside P_T.  The numeric
    update is then

        X           = panel_T[:, col_map]                (gather)
        L_TS        = X[:, :k] @ inv(U_SS)               (dense TRSM, k = nr_S)
        X[:, k:]   -= L_TS @ U_S,rest                    (GEMM  — sup-sup)
        panel_T[:, col_map] = [L_TS | X[:, k:]]          (scatter)

    For a standalone source row (k == 1) this degenerates to the row-row /
    sup-row kernels (a divide + an axpy / GEMV); for supernode sources it is
    the sup-sup kernel (TRSM+GEMM on the MXU).  One code path, three kernels —
    exactly the paper's "common data structure" idea, expressed as shapes.

Exactness of full-panel updates: if row t of T is not in struct(U row s) for
any s in S, then the gathered X[t, S-block] is exactly zero (its entries would
otherwise be symbolic fill — contradiction), so the TRSM row is zero and the
GEMM adds zeros.  Hence updating *all* rows of the target panel is exact; the
cost is redundant-flop padding, which is the honest TPU price for regularity
and is reported by ``plan_stats`` (useful_flops vs padded_flops).

Node-level symbolic structures are computed here bottom-up (P_T from A-rows
plus incoming W_S cliques), which keeps edge scatter maps consistent by
construction, including under relaxed amalgamation.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .matrix import CSR
from .symbolic import Symbolic


@dataclasses.dataclass
class Edge:
    src: int
    col_map: np.ndarray     # (k_src + m_src,) positions into target pattern


@dataclasses.dataclass
class NodePlan:
    nid: int
    r0: int
    r1: int                 # exclusive; nr = r1 - r0
    pattern: np.ndarray     # sorted global col ids, len w
    lsize: int              # cols < r0
    usize: int              # cols >= r1
    edges: list             # list[Edge], ascending src
    level: int = -1

    @property
    def nr(self) -> int:
        return self.r1 - self.r0

    @property
    def width(self) -> int:
        return len(self.pattern)


@dataclasses.dataclass
class FactorPlan:
    n: int
    nodes: list                 # list[NodePlan]
    panel_offset: np.ndarray    # (n_nodes+1,) flat offsets; panel T occupies
                                # [off[T], off[T] + nr*w) row-major
    total_slots: int
    a_scatter: np.ndarray       # (nnz_B,) flat positions of B entries
    levels: list                # list[np.ndarray] node ids per level
    n_bulk_levels: int          # prefix of `levels` executed in bulk mode
    mode: str                   # "hybrid" | "supernodal" | "rowrow"
    useful_flops: float
    padded_flops: float
    row_perm_slots: np.ndarray  # (n,) flat position of each row's diag entry

    @property
    def n_nodes(self):
        return len(self.nodes)


def build_plan(pat_sym: CSR, numeric: CSR, sym: Symbolic, mode: str = "hybrid",
               bulk_min_width: int = 8) -> FactorPlan:
    """Build the static plan.

    pat_sym — symmetrized pattern (B+Bᵀ+I) the symbolic analysis ran on;
              node structures MUST use it (fill comes from the symmetric
              pattern even where B itself has a numeric zero).
    numeric — the actual matrix pattern (drives the A-value scatter map).
    """
    n = pat_sym.n
    n_nodes = sym.n_nodes
    starts, ends = sym.snode_start, sym.snode_end

    # ---------------- node-level pattern recursion (ascending) -------------
    patterns: list[np.ndarray] = [None] * n_nodes      # type: ignore
    w_structs: list[np.ndarray] = [None] * n_nodes     # type: ignore
    src_lists: list[list[int]] = [[] for _ in range(n_nodes)]

    snode_of = sym.snode_of
    for t in range(n_nodes):
        r0, r1 = int(starts[t]), int(ends[t])
        cols_parts = [np.arange(r0, r1, dtype=np.int64)]
        for i in range(r0, r1):
            idx, _ = pat_sym.row(i)
            cols_parts.append(idx.astype(np.int64))
        for s in src_lists[t]:
            sp = patterns[s]
            node_s0 = int(starts[s])
            # all of S's block + U cols (suffix of its pattern from lsize on)
            cols_parts.append(sp[np.searchsorted(sp, node_s0):])
        pat = np.unique(np.concatenate(cols_parts))
        patterns[t] = pat
        w = pat[np.searchsorted(pat, r1):]
        w_structs[t] = w
        # register this node as a source of every node its W hits
        hit_nodes = np.unique(snode_of[w])
        for h in hit_nodes:
            src_lists[int(h)].append(t)

    # ---------------- edges + maps ----------------------------------------
    nodes: list[NodePlan] = []
    useful = 0.0
    padded = 0.0
    for t in range(n_nodes):
        r0, r1 = int(starts[t]), int(ends[t])
        pat = patterns[t]
        lsize = int(np.searchsorted(pat, r0))
        usize = int(len(pat) - np.searchsorted(pat, r1))
        edges = []
        for s in sorted(src_lists[t]):
            sp = patterns[s]
            s0 = int(starts[s])
            src_cols = sp[np.searchsorted(sp, s0):]      # block + W_S
            pos = np.searchsorted(pat, src_cols)
            assert np.array_equal(pat[pos], src_cols), \
                "plan inconsistency: source cols missing from target pattern"
            edges.append(Edge(src=s, col_map=pos.astype(np.int64)))
            k = int(ends[s] - s0)
            m = len(src_cols) - k
            nr = r1 - r0
            h = int(np.sum((w_structs[s] >= r0) & (w_structs[s] < r1)))
            useful += 2.0 * h * k * (k + m)   # trsm+gemm on hit rows
            padded += 2.0 * nr * k * (k + m)
        nr = r1 - r0
        wdt = len(pat)
        useful += 2.0 / 3.0 * nr ** 3 + 2.0 * nr * nr * (wdt - lsize - nr)
        padded += 2.0 / 3.0 * nr ** 3 + 2.0 * nr * nr * (wdt - lsize - nr)
        nodes.append(NodePlan(nid=t, r0=r0, r1=r1, pattern=pat,
                              lsize=lsize, usize=usize, edges=edges))

    # ---------------- flat panel layout ------------------------------------
    panel_offset = np.zeros(n_nodes + 1, dtype=np.int64)
    for t, nd in enumerate(nodes):
        panel_offset[t + 1] = panel_offset[t] + nd.nr * nd.width
    total_slots = int(panel_offset[-1])

    # ---------------- A-value scatter map ----------------------------------
    a_scatter = np.empty(numeric.nnz, dtype=np.int64)
    for i in range(n):
        t = int(snode_of[i])
        nd = nodes[t]
        s, e = numeric.indptr[i], numeric.indptr[i + 1]
        pos = np.searchsorted(nd.pattern, numeric.indices[s:e])
        assert np.array_equal(nd.pattern[pos], numeric.indices[s:e]), \
            "numeric entry outside node pattern"
        a_scatter[s:e] = (panel_offset[t] + (i - nd.r0) * nd.width + pos)

    row_perm_slots = np.empty(n, dtype=np.int64)
    for i in range(n):
        t = int(snode_of[i])
        nd = nodes[t]
        dpos = nd.lsize + (i - nd.r0)
        row_perm_slots[i] = panel_offset[t] + (i - nd.r0) * nd.width + dpos

    # ---------------- levelization: dual-mode schedule ----------------------
    level = np.zeros(n_nodes, dtype=np.int64)
    for t, nd in enumerate(nodes):
        lv = 0
        for e in nd.edges:
            lv = max(lv, level[e.src] + 1)
        level[t] = lv
        nd.level = int(lv)
    n_levels = int(level.max()) + 1 if n_nodes else 0
    levels = [np.where(level == lv)[0] for lv in range(n_levels)]
    n_bulk = 0
    for lv in range(n_levels):
        if len(levels[lv]) >= bulk_min_width:
            n_bulk = lv + 1
        else:
            break

    return FactorPlan(n=n, nodes=nodes, panel_offset=panel_offset,
                      total_slots=total_slots, a_scatter=a_scatter,
                      levels=levels, n_bulk_levels=n_bulk, mode=mode,
                      useful_flops=useful, padded_flops=padded,
                      row_perm_slots=row_perm_slots)


def memory_stats(plan: FactorPlan, bulk_min_width: int = 8, k: int = 1,
                 dtype_bytes: int = 8) -> dict:
    """Deterministic plan-derived byte accounting of the numeric phase —
    what the repeated-solve engine resident set looks like BEFORE running
    it, so scale benchmarks can report (and CI can regression-check) a
    footprint that does not depend on allocator noise.

    ``panel_bytes``     one set of factor values (``total_slots`` slots);
    ``workspace_bytes`` the factor working buffer incl. the zero/one/scratch
                        sentinel slots (``n_ext``);
    ``schedule_index_bytes``  every static gather/scatter index array of the
                        bucketed schedule (the compile-time trace payload);
    ``batched_bytes``   value + RHS + solution buffers for a system batch of
                        ``k`` (the batched refactor path's per-K cost);
    ``total_bytes``     the sum — the engine's steady-state floor."""
    from .structure import get_bucket_schedule

    sched = get_bucket_schedule(plan, bulk_min_width=bulk_min_width)
    idx = 0
    for s in sched.steps:
        if s.diag is not None:
            idx += s.diag.nids.nbytes + s.diag.slots.nbytes
        idx += s.seq.nbytes
        for pb in s.panels:
            idx += (pb.nids.nbytes + pb.gather.nbytes + pb.scatter.nbytes
                    + pb.rows.nbytes)
        for eb in s.edges:
            idx += (eb.srcs.nbytes + eb.tgts.nbytes + eb.src_idx.nbytes
                    + eb.x_idx.nbytes + eb.write_idx.nbytes)
    for c in sched.scan_chunks:
        idx += (c.dsl.nbytes + c.x_idx.nbytes + c.src_idx.nbytes
                + c.write_idx.nbytes)
    panel = plan.total_slots * dtype_bytes
    workspace = sched.n_ext * dtype_bytes
    batched = k * (sched.n_ext + 2 * plan.n) * dtype_bytes
    return dict(
        panel_bytes=int(panel),
        workspace_bytes=int(workspace),
        schedule_index_bytes=int(idx),
        batched_bytes=int(batched),
        total_bytes=int(panel + workspace + idx + batched),
    )


def plan_stats(plan: FactorPlan, include_buckets: bool = True,
               bulk_min_width: int = 8) -> dict:
    """Plan statistics; with ``include_buckets`` (default) also the
    level-bucketed factor schedule's bucket counts, pad-waste fraction and
    bulk-node coverage — the numbers to revisit ``kernel_select``
    thresholds against (a mode that looks good on padded_flops can still
    lose on pad_waste_frac / trace size).  Pass the analysis's
    ``opts.bulk_min_width`` so the bucket stats describe the schedule the
    engine actually runs."""
    widths = np.array([nd.width for nd in plan.nodes])
    nrs = np.array([nd.nr for nd in plan.nodes])
    n_edges = sum(len(nd.edges) for nd in plan.nodes)
    bucket = {}
    mem = {}
    if include_buckets:
        from .structure import bucket_stats
        bucket = bucket_stats(plan, bulk_min_width=bulk_min_width)
        mem = memory_stats(plan, bulk_min_width=bulk_min_width)
    return dict(
        **bucket,
        **mem,
        mode=plan.mode,
        n_nodes=plan.n_nodes,
        n_edges=n_edges,
        total_slots=plan.total_slots,
        mean_panel_width=float(widths.mean()) if len(widths) else 0.0,
        mean_nr=float(nrs.mean()) if len(nrs) else 0.0,
        n_levels=len(plan.levels),
        n_bulk_levels=plan.n_bulk_levels,
        bulk_node_frac=float(sum(len(plan.levels[i]) for i in range(plan.n_bulk_levels))
                             / max(plan.n_nodes, 1)),
        useful_flops=plan.useful_flops,
        padded_flops=plan.padded_flops,
        padding_overhead=plan.padded_flops / max(plan.useful_flops, 1.0),
    )
