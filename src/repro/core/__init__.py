"""repro.core — HYLU: hybrid parallel sparse LU factorization (the paper's
contribution) as a composable JAX module.

Public API (layered: options → analysis → batched → api facade, with the
plan cache on top; serving lives in repro.serve.solver_service):
    CSR                       sparse container
    HyluOptions               solver options (mode/ordering/engine knobs)
    analyze / factor / refactor / solve / solve_system
    factor_batched / solve_batched / solve_sequence
                              batched repeated-solve path: K value sets of
                              one pattern factored+solved as one XLA
                              program — sharded over devices via
                              HyluOptions.mesh, with solve_sequence's
                              async double-buffered T-step pipeline
                              (HyluOptions.donate recycles buffers)
    jax_repeated_engine       pre-compiled per-analysis jax engine bundle
    pattern_key / plan_fingerprint
                              content address of an analysis artifact
    PlanCache / save_analysis / load_analysis
                              content-addressed LRU plan cache with disk
                              persistence under checkpoints/plan_cache
    make_sparse_solve         differentiable jittable solver (custom_vjp)
    baselines                 pardiso_like / klu_like option presets
"""
from .matrix import CSR
from .api import (HyluOptions, Analysis, FactorState, BatchedFactorState,
                  analyze, factor, refactor, solve, solve_system,
                  factor_batched, solve_batched, solve_sequence,
                  jax_repeated_engine, pattern_key, plan_fingerprint)
from .plan_cache import PlanCache, save_analysis, load_analysis
from .autodiff import make_sparse_solve
from . import baseline as baselines
