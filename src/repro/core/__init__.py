"""repro.core — HYLU: hybrid parallel sparse LU factorization (the paper's
contribution) as a composable JAX module.

Public API:
    CSR                       sparse container
    HyluOptions               solver options (mode/ordering/pivoting knobs)
    analyze / factor / refactor / solve / solve_system
    make_sparse_solve         differentiable jittable solver (custom_vjp)
    baselines                 pardiso_like / klu_like option presets
"""
from .matrix import CSR
from .api import (HyluOptions, Analysis, FactorState, analyze, factor,
                  refactor, solve, solve_system)
from .autodiff import make_sparse_solve
from . import baseline as baselines
