"""Symbolic factorization (HYLU preprocessing step 3).

Given the statically-pivoted, reordered matrix B (pattern symmetrized to
B+Bᵀ+I — the discipline of every static-pivoting solver: numeric pivoting is
then restricted to supernode diagonal blocks plus pivot perturbation, so the
symbolic structure "will not change during numerical factorization" exactly
as HYLU §2.1 requires), compute:

  - the elimination tree (Liu's algorithm with path compression),
  - per-row structures of L  (== per-column structures of U transposed),
  - per-column structures of L (== U row structures; supernodes share these),
  - FLOP counts per row/total (drives HYLU's kernel selection),
  - the supernode partition: maximal runs of consecutive rows with identical
    U-structure (fundamental supernodes: parent[j]==j+1 ∧ cc[j]==cc[j+1]+1),
    with optional relaxed amalgamation and a width cap (MXU panel geometry).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .matrix import CSR


# --------------------------------------------------------------------------
# elimination tree + column counts
# --------------------------------------------------------------------------
def etree(pat: CSR) -> np.ndarray:
    """Elimination tree of a symmetric pattern (diag included), parent[-1]=-1
    for roots. Liu's algorithm with path compression."""
    n = pat.n
    parent = np.full(n, -1, dtype=np.int64)
    ancestor = np.full(n, -1, dtype=np.int64)
    for i in range(n):
        idx, _ = pat.row(i)
        for j in idx:
            j = int(j)
            if j >= i:
                continue
            # walk from j to the root of its current subtree
            while True:
                a = ancestor[j]
                ancestor[j] = i
                if a < 0:
                    if parent[j] < 0 and j != i:
                        parent[j] = i
                    break
                if a == i:
                    break
                j = a
    return parent


def etree_col_counts(pat: CSR, abort_nnz: float | None = None) -> np.ndarray:
    """Column counts of L (incl. diagonal) via row-subtree walks. O(|L|).
    abort_nnz: stop early once total fill exceeds this budget (ordering
    selection prunes hopeless candidates without paying their full fill)."""
    n = pat.n
    parent = etree(pat)
    mark = np.full(n, -1, dtype=np.int64)
    cc = np.ones(n, dtype=np.int64)  # diagonal
    total = n
    for i in range(n):
        mark[i] = i
        idx, _ = pat.row(i)
        for j in idx:
            j = int(j)
            if j >= i:
                continue
            while j != -1 and mark[j] != i:
                cc[j] += 1          # l_{i,j} is structurally nonzero
                total += 1
                mark[j] = i
                j = int(parent[j])
        if abort_nnz is not None and total > abort_nnz:
            cc[:] = n              # pessimize: candidate is hopeless
            return cc
    return cc


@dataclasses.dataclass
class Symbolic:
    n: int
    parent: np.ndarray            # etree
    # L row structures (strictly below diag), CSR-style:
    lrow_ptr: np.ndarray          # (n+1,)
    lrow_idx: np.ndarray          # column ids, ascending per row
    # L column structures (strictly below diag) == U row structures:
    lcol_ptr: np.ndarray          # (n+1,)
    lcol_idx: np.ndarray          # row ids, ascending per column
    cc: np.ndarray                # |L col j| incl diag
    flops: float                  # total factorization flops (2*cc^2 sum)
    row_flops: np.ndarray         # per-row update flops
    # supernode partition:
    snode_of: np.ndarray          # (n,) node id per row
    snode_start: np.ndarray       # (n_nodes,)
    snode_end: np.ndarray         # (n_nodes,) exclusive
    nnz_l: int

    @property
    def n_nodes(self) -> int:
        return len(self.snode_start)

    def node_rows(self, t: int):
        return int(self.snode_start[t]), int(self.snode_end[t])

    def urow_struct(self, j: int) -> np.ndarray:
        """struct(U row j) beyond the diagonal == struct(L col j)."""
        s, e = self.lcol_ptr[j], self.lcol_ptr[j + 1]
        return self.lcol_idx[s:e]

    def lrow_struct(self, i: int) -> np.ndarray:
        s, e = self.lrow_ptr[i], self.lrow_ptr[i + 1]
        return self.lrow_idx[s:e]


def symbolic_factorize(pat: CSR, relax: int = 8, max_super: int = 128,
                       do_supernodes: bool = True) -> Symbolic:
    """Full symbolic analysis on a symmetric pattern.

    relax: a supernode may absorb its parent run if the union structure adds
           at most `relax` fill rows per column (relaxed amalgamation).
    max_super: supernode width cap (panels are padded to MXU tiles on TPU;
           capping bounds padding waste and VMEM footprint).
    do_supernodes: False → every row is a standalone node (row-row plan).
    """
    n = pat.n
    parent = etree(pat)

    # --- row structures via etree walks; also collect column structures
    mark = np.full(n, -1, dtype=np.int64)
    lrow_lists: list[list[int]] = [None] * n  # type: ignore
    col_counts = np.zeros(n, dtype=np.int64)
    for i in range(n):
        mark[i] = i
        acc: list[int] = []
        idx, _ = pat.row(i)
        for j in idx:
            j = int(j)
            if j >= i:
                continue
            while j != -1 and mark[j] != i:
                acc.append(j)
                mark[j] = i
                j = int(parent[j])
        acc.sort()
        lrow_lists[i] = acc
        col_counts[np.array(acc, dtype=np.int64)] += 1 if acc else 0

    lrow_ptr = np.zeros(n + 1, dtype=np.int64)
    lrow_ptr[1:] = np.cumsum([len(x) for x in lrow_lists])
    lrow_idx = np.concatenate([np.array(x, dtype=np.int64) for x in lrow_lists]) \
        if lrow_ptr[-1] else np.empty(0, np.int64)

    # --- column structures by bucketing rows (ascending row id per col)
    lcol_ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(lcol_ptr, lrow_idx + 1, 1)
    lcol_ptr = np.cumsum(lcol_ptr)
    lcol_idx = np.empty(lrow_ptr[-1], dtype=np.int64)
    fill_pos = lcol_ptr[:-1].copy()
    rows_of = np.repeat(np.arange(n), np.diff(lrow_ptr))
    for k in range(len(lrow_idx)):      # rows visited ascending → sorted cols
        j = lrow_idx[k]
        lcol_idx[fill_pos[j]] = rows_of[k]
        fill_pos[j] += 1

    cc = np.diff(lcol_ptr) + 1          # incl diagonal
    # per-row update flops: row i costs sum over j in lrow(i) of 2*|U row j ∩ (j, n)|
    urow_len = np.diff(lcol_ptr)        # |struct(U row j)| beyond diag
    row_flops = np.zeros(n, dtype=np.float64)
    if len(lrow_idx):
        np.add.at(row_flops, rows_of, 2.0 * (urow_len[lrow_idx] + 1))
    flops = float(row_flops.sum())

    # --- supernodes
    if do_supernodes:
        snode_start, snode_end = _detect_supernodes(
            parent, cc, n, relax=relax, max_super=max_super,
            lcol_ptr=lcol_ptr, lcol_idx=lcol_idx)
    else:
        snode_start = np.arange(n, dtype=np.int64)
        snode_end = snode_start + 1
    snode_of = np.zeros(n, dtype=np.int64)
    for t in range(len(snode_start)):
        snode_of[snode_start[t]:snode_end[t]] = t

    return Symbolic(n=n, parent=parent, lrow_ptr=lrow_ptr, lrow_idx=lrow_idx,
                    lcol_ptr=lcol_ptr, lcol_idx=lcol_idx, cc=cc, flops=flops,
                    row_flops=row_flops, snode_of=snode_of,
                    snode_start=np.asarray(snode_start, dtype=np.int64),
                    snode_end=np.asarray(snode_end, dtype=np.int64),
                    nnz_l=int(lrow_ptr[-1]))


def _detect_supernodes(parent, cc, n, relax, max_super, lcol_ptr, lcol_idx):
    """Fundamental supernodes + relaxed amalgamation + width cap."""
    starts = [0]
    for j in range(1, n):
        fundamental = (parent[j - 1] == j) and (cc[j - 1] == cc[j] + 1)
        width = j - starts[-1]
        if fundamental and width < max_super:
            continue
        # relaxed amalgamation: allow tiny structure mismatch
        if (relax > 0 and parent[j - 1] == j and width < max_super
                and 0 <= cc[j - 1] - cc[j] - 1 <= relax
                and width <= 4 * relax):
            continue
        starts.append(j)
    starts = np.array(starts, dtype=np.int64)
    ends = np.append(starts[1:], n)
    return starts, ends


# --------------------------------------------------------------------------
# statistics (drive kernel selection)
# --------------------------------------------------------------------------
def symbolic_stats(sym: Symbolic) -> dict:
    widths = (sym.snode_end - sym.snode_start)
    in_super = widths[widths >= 2].sum()
    nnz_lu = 2 * sym.nnz_l + sym.n
    return dict(
        n=sym.n,
        nnz_l=sym.nnz_l,
        nnz_lu=nnz_lu,
        flops=sym.flops,
        flops_per_nnz=sym.flops / max(nnz_lu, 1),
        n_nodes=sym.n_nodes,
        n_supernodes=int((widths >= 2).sum()),
        supernode_coverage=float(in_super) / max(sym.n, 1),
        mean_supernode_width=float(widths[widths >= 2].mean()) if (widths >= 2).any() else 0.0,
        mean_urow_len=float(np.diff(sym.lcol_ptr).mean()) if sym.n else 0.0,
    )
