"""Differentiable sparse solve (beyond-paper feature).

``make_sparse_solve(analysis)`` returns a jittable ``f(a_data, b) -> x``
solving A x = b with HYLU factors, equipped with an implicit-function-theorem
custom VJP:

    b̄        = A⁻ᵀ x̄                     (transpose solve, same LU factors)
    ā_(i,j)  = -(A⁻ᵀ x̄)_i · x_j           (one fused gather per nnz)

The adjoint reuses the forward factorization — the numerical analogue of
HYLU's repeated-solve path — so a training loop that backprops through the
solver pays one factorization and two triangular solves per step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .api import Analysis
from .jax_engine import make_factor_fn, make_lu_solver, make_permuted_apply
from .options import resolve_perturb_eps
from .structure import build_solve_structure


def make_sparse_solve(an: Analysis, dtype=jnp.float64, use_pallas: bool = False,
                      interpret: bool = True):
    """Emit the differentiable solver for a fixed sparsity pattern."""
    plan = an.plan
    ss = build_solve_structure(plan, bulk_min_width=an.opts.bulk_min_width)
    factor_fn = make_factor_fn(plan,
                               perturb_eps=resolve_perturb_eps(an.opts, dtype),
                               dtype=dtype, use_pallas=use_pallas,
                               interpret=interpret)
    lu_solve, lut_solve = make_lu_solver(ss, dtype=dtype)

    n = an.n
    p_ = jnp.asarray(an.p)
    q_ = jnp.asarray(an.q)
    r_ = jnp.asarray(an.match.row_scale, dtype=dtype)
    s_ = jnp.asarray(an.match.col_scale, dtype=dtype)
    src_map = jnp.asarray(an.src_map)
    scale_map = jnp.asarray(an.scale_map, dtype=dtype)
    # original-pattern (row, col) per nnz for the A-values cotangent
    indptr, indices = an.m_pattern  # M pattern; invert src_map below.

    lu_apply = make_permuted_apply(lu_solve, an.n, an.p, an.q,
                                   an.match.row_scale, an.match.col_scale,
                                   dtype=dtype)

    def _fwd_impl(a_data, b):
        a_data = a_data.astype(dtype)
        m_data = a_data[src_map] * scale_map
        f = factor_fn(m_data)
        return lu_apply(f.vals, f.inode_perm, b), f

    @jax.custom_vjp
    def sparse_solve(a_data, b):
        return _fwd_impl(a_data, b)[0]

    def fwd(a_data, b):
        x, f = _fwd_impl(a_data, b)
        return x, (f.vals, f.inode_perm, x)

    def bwd(res, g):
        vals, inode, x = res
        t = (s_ * g.astype(dtype))[q_][p_]
        t = lut_solve(vals, t)
        t = jnp.zeros(n, dtype).at[inode].set(t)
        lam = r_ * jnp.zeros(n, dtype).at[p_].set(t)
        abar = -(lam[rows_a] * x[cols_a])
        return abar, lam

    sparse_solve.defvjp(fwd, bwd)

    # host: original A pattern (rows/cols per nnz) — recover from analysis:
    # an.m_pattern is M's; the tracked src_map tells which A entry each M
    # entry came from, so invert.
    nnz = len(an.src_map)
    m_rows = np.repeat(np.arange(n), np.diff(indptr))
    m_cols = np.asarray(indices)
    # M[i,j] = scaled A[src]; A entry src sits at original (row,col): we can
    # reconstruct A's (row, col): row = p[m_row] pre-ordering is B2's row;
    # B2 row == A row; B2 col j maps to A col q[j].
    a_rows_np = np.empty(nnz, dtype=np.int64)
    a_cols_np = np.empty(nnz, dtype=np.int64)
    a_rows_np[an.src_map] = an.p[m_rows]
    a_cols_np[an.src_map] = an.q[an.p[m_cols]]
    rows_a = jnp.asarray(a_rows_np)
    cols_a = jnp.asarray(a_cols_np)

    return sparse_solve
