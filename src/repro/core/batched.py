"""Batched repeated solve: K value sets of one pattern as one XLA program.

Top numeric layer of the core stack (options → analysis → batched → api
facade).  Lifts the numeric phase over K value sets of one sparsity pattern
as single pre-compiled XLA programs, optionally sharded across devices over
the system-batch axis (``HyluOptions.mesh``) with an async double-buffered,
buffer-donating sequence pipeline (``HyluOptions.donate``).  Everything here
consumes an :class:`repro.core.analysis.Analysis` and its cached engines —
the serving layer (:mod:`repro.serve.solver_service`) dispatches
heterogeneous traffic onto these entry points, one group per pattern.
"""
from __future__ import annotations

import dataclasses
import time
import numpy as np

from .matrix import CSR
from .analysis import Analysis, analyze, jax_repeated_engine
from .options import HyluOptions, resolve_refine_tol


@dataclasses.dataclass
class BatchedFactorState:
    """K factorizations of one sparsity pattern (K value sets), held as
    stacked device arrays — the state of the batched repeated-solve path.

    Under a mesh (``HyluOptions.mesh``) the device arrays are padded from K
    up to ``k_pad`` (a multiple of the device count) and sharded over the
    mesh's system-batch axis; ``k`` is always the caller's true batch size
    and every result is sliced back to it."""
    analysis: Analysis
    a_pattern: tuple           # (indptr, indices) of the original matrices
    values_dev: object         # jax (K_pad, nnz) A values on device (fused
                               # residuals — staged once, not per solve)
    vals: object               # jax (K_pad, total_slots) factored panels
    inode_perm: object         # jax (K_pad, n) in-node pivot permutations
    n_perturb: np.ndarray      # (K,) perturbation counts
    timings: dict
    k: int                     # true batch size (≤ k_pad)
    consumed: bool = False     # buffers donated away by solve_batched(
                               # donate=True) — the state is spent
    _values_host: np.ndarray | None = dataclasses.field(default=None,
                                                        repr=False)

    @property
    def k_pad(self) -> int:
        return int(self.vals.shape[0])

    @property
    def values_batch(self) -> np.ndarray:
        """(K, nnz) host mirror of the A values — the oracle the host-loop
        baseline and tests diff against.  Materialized lazily: when the
        caller committed device buffers (no host copy ever existed), the
        first access is one device→host transfer."""
        if self._values_host is None:
            self._values_host = np.asarray(self.values_dev)[:self.k]
        return self._values_host


def _pattern_of(a_pattern) -> tuple:
    if isinstance(a_pattern, CSR):
        return (a_pattern.indptr, a_pattern.indices)
    indptr, indices = a_pattern
    return (np.asarray(indptr), np.asarray(indices))


def _batched_matvec(pattern: tuple, values_batch: np.ndarray,
                    x_batch: np.ndarray) -> np.ndarray:
    """(A_k x_k) for K CSR matrices sharing one pattern: one gather +
    row-segment reduction for the whole batch.

    Host-side (numpy) reference: the production jax path computes residuals
    with the device matvec baked into the fused solver
    (``jax_engine.make_csr_matvec_batched``); this stays as the oracle for
    tests and as the host-loop benchmark baseline.  x_batch is (K, n) or
    (K, n, m) multi-RHS."""
    indptr, indices = pattern
    if x_batch.ndim == 3:
        prod = values_batch[:, :, None] * x_batch[:, indices]
    else:
        prod = values_batch * x_batch[:, indices]
    counts = np.diff(indptr)
    if len(counts) == 0:
        return np.zeros_like(x_batch)
    if counts.min() > 0:
        return np.add.reduceat(prod, indptr[:-1], axis=1)
    # reduceat mishandles empty rows; fall back to per-batch scatter-add
    # (preserves the batch dtype, unlike bincount which promotes to float64)
    seg = np.repeat(np.arange(len(counts)), counts)
    out = np.zeros((x_batch.shape[0], len(counts)) + x_batch.shape[2:],
                   dtype=prod.dtype)
    for k in range(out.shape[0]):
        np.add.at(out[k], seg, prod[k])
    return out


def _pad_k(eng, k: int) -> int:
    """K padded up to a multiple of the engine's shard count."""
    return -(-k // eng.n_shards) * eng.n_shards


def _stage_values(eng, values_batch):
    """Stage a (K, nnz) value set on device for the batched engine.

    Honors committed device buffers: a jax array input is used in place —
    no device→host→device round-trip (the pre-sharding code always pulled
    values through numpy).  K is padded to a multiple of the mesh device
    count by replicating system 0 (well-conditioned; padded systems are
    masked out of every result), and the buffer is placed with the
    engine's batch sharding.  Returns ``(values_dev (K_pad, nnz),
    values_host | None, k)`` — ``values_host`` is the (K, nnz) oracle in
    the engine's ``values_dtype`` when the input came from the host, else
    None (materialized lazily by ``BatchedFactorState.values_batch``).

    Staging honors the engine's ``values_dtype`` — the refine-precision
    dtype the fused residual matvec runs against: float64 for a pure-fp64
    or a mixed reduced-factor engine (the original-precision values are
    what refinement recovers accuracy from), the factor dtype for a pure
    reduced-precision engine (no silent fp64 upcast + double copy)."""
    import jax
    import jax.numpy as jnp

    if isinstance(values_batch, jax.Array):
        v = values_batch if values_batch.ndim > 1 else values_batch[None]
        host = None
        k = int(v.shape[0])
        k_pad = _pad_k(eng, k)
        if k_pad != k:
            v = jnp.concatenate(
                [v, jnp.broadcast_to(v[:1], (k_pad - k, v.shape[1]))])
    else:
        host = np.ascontiguousarray(
            np.atleast_2d(np.asarray(values_batch,
                                     dtype=np.dtype(eng.values_dtype))))
        k = host.shape[0]
        k_pad = _pad_k(eng, k)
        v = host if k_pad == k else np.concatenate(
            [host, np.broadcast_to(host[:1], (k_pad - k, host.shape[1]))])
    if eng.batch_sharding is not None:
        v = jax.device_put(v, eng.batch_sharding)
    elif not isinstance(v, jax.Array):
        v = jnp.asarray(v)
    return v, host, k


def _stage_rhs(eng, b_batch, k: int, copy: bool = False):
    """Stage right-hand sides (K, n) / (n,) broadcast / (K, n, m) on device:
    same device-buffer honoring, zero-padding of K to the mesh multiple
    (zero RHS ⇒ the padded systems converge on iteration 0), and batch
    sharding placement.  A leading dimension that matches neither K nor 1
    raises (it must not silently zero-pad a mis-sized batch).

    copy=True forces a fresh device buffer even when the input is already
    a correctly-shaped jax array — required when the staged buffer will be
    *donated* but the source must survive (the pipeline re-stages a shared
    RHS every step)."""
    import jax
    import jax.numpy as jnp

    k_pad = _pad_k(eng, k)
    if getattr(b_batch, "ndim", 1) > 1 and b_batch.shape[0] != k:
        raise ValueError(f"b_batch has leading (batch) dimension "
                         f"{b_batch.shape[0]} but the factorization batch "
                         f"size is {k}")
    if isinstance(b_batch, jax.Array):
        b = b_batch
        if b.ndim == 1:
            b = jnp.broadcast_to(b, (k,) + b.shape)
        if k_pad != k:
            b = jnp.concatenate(
                [b, jnp.zeros((k_pad - k,) + b.shape[1:], b.dtype)])
        elif copy and b is b_batch:
            b = jnp.array(b)                     # fresh, donatable buffer
    else:
        b = np.asarray(b_batch, dtype=np.dtype(eng.values_dtype))
        if b.ndim == 1:
            b = np.broadcast_to(b, (k,) + b.shape)
        if k_pad != k:
            b = np.concatenate(
                [b, np.zeros((k_pad - k,) + b.shape[1:], dtype=b.dtype)])
    if eng.batch_sharding is not None:
        return jax.device_put(b, eng.batch_sharding)
    return jnp.asarray(b)


def factor_batched(an: Analysis, a_pattern, values_batch) -> BatchedFactorState:
    """K numeric factorizations (one pattern, K value sets) as a single
    pre-compiled vmapped XLA call — HYLU's repeated-solve optimization
    lifted to a batch.

    ``values_batch`` may be a host (K, nnz) array or a committed jax device
    array (no re-upload).  With ``an.opts.mesh`` set the call is sharded
    over the system-batch axis: K is padded to a multiple of the device
    count and each device factors its shard with the identical per-system
    program (bit-identical to the single-device path)."""
    import jax

    eng = jax_repeated_engine(an)
    t = {}
    t0 = time.perf_counter()
    values_dev, values_host, k = _stage_values(eng, values_batch)
    jf = eng.refactor_batched(values_dev)
    jax.block_until_ready(jf.vals)
    t["factor_batched"] = time.perf_counter() - t0
    return BatchedFactorState(
        analysis=an, a_pattern=_pattern_of(a_pattern),
        values_dev=values_dev, vals=jf.vals, inode_perm=jf.inode_perm,
        n_perturb=np.asarray(jf.n_perturb)[:k], timings=t, k=k,
        _values_host=values_host)


def solve_batched(bst: BatchedFactorState, b_batch: np.ndarray,
                  refine: bool | None = None, donate: bool = False) -> tuple:
    """Batched substitution + iterative refinement, fused on device: X[k]
    solves A_k x = b_k against the K stored factorizations as ONE
    pre-compiled XLA program — substitution, the batched CSR residual
    matvec (pattern as compile-time constants) and the whole refinement
    loop (``lax.while_loop`` with per-system improved/converged masking)
    execute without any per-iteration host transfer.  Under a mesh the
    program is shard_mapped over the system batch (padded K; results are
    sliced back and bit-identical to the single-device path).

    b_batch: (K, n), (n,) broadcast across the batch, or (K, n, m)
    multi-RHS (adjoint/sensitivity workloads); host or committed jax
    arrays.  Returns (X, info); info["residual"] is (K,) — or (K, m) for
    multi-RHS — and info["n_refine_per_system"] counts accepted refinement
    steps per system/RHS.  refine=False skips refinement; refine=None/True
    runs it until converged, stalled, or refine_max_iter.
    info["refine_failed"] / info["refine_stalled"] are the per-system
    masks from the fused loop: systems that exited refinement above the
    (dtype-aware) tolerance, and the subset that stopped improving.
    info["escalation"] lists the recovery stages this call ran ("refine",
    then "fp64_fallback" when the escape hatch redid a failed subset) —
    the serving layer's escalation ladder appends its own perturbed-retry
    stages on top of this record.

    On a reduced-precision engine (``factor_dtype != "float64"`` with
    fp64-staged values, i.e. the default mixed path) any refinement-failed
    system is automatically re-factored and re-solved in float64 — batched,
    failed subset only — when ``opts.fp64_fallback`` is set:
    info["fallback_mask"] marks the redone systems, info["n_fp64_fallback"]
    counts them, and the returned x/residual/masks reflect the fp64 redo,
    so callers always get fp64-quality answers or an honest failure mask.

    donate=True donates the A-values and RHS buffers into the call (the
    sequence-pipeline mode): XLA may reuse their memory, and ``bst`` is
    marked consumed — further solves against it raise."""
    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    if bst.consumed:
        raise RuntimeError(
            "this BatchedFactorState was consumed by a donating solve — "
            "refactor (factor_batched) before solving again")
    t0 = time.perf_counter()
    max_iter = 0 if refine is False else opts.refine_max_iter
    # the escape hatch needs the original fp64 values, so it only arms on a
    # reduced-factor engine whose staging (= refine) dtype is float64
    fallback_armed = (
        max_iter > 0 and bool(opts.fp64_fallback)
        and np.dtype(eng.factor_dtype) != np.float64
        and np.dtype(eng.values_dtype) == np.float64)
    if donate and bst._values_host is None:
        _ = bst.values_batch    # materialize the host oracle before the
        #                         device buffer is donated away
    b_dev = _stage_rhs(eng, b_batch, bst.k)
    # a donated RHS buffer dies with the call — snapshot it while the
    # fallback might still need to re-solve a failed subset
    b_src = np.asarray(b_dev) if (donate and fallback_armed) else b_dev
    solver = eng.refined_batched_solver(*bst.a_pattern, donate=donate)
    x, resid, n_iter, n_ref_sys, stalled, failed = solver(
        bst.vals, bst.inode_perm, bst.values_dev,
        b_dev, max_iter, resolve_refine_tol(opts, eng.refine_dtype))
    if donate:
        bst.consumed = True
        bst.values_dev = None
    k = bst.k
    x = np.asarray(x)[:k]
    failed_h = np.asarray(failed)[:k]
    info = dict(residual=np.asarray(resid)[:k], n_refine=int(n_iter),
                n_refine_per_system=np.asarray(n_ref_sys)[:k],
                n_perturb=bst.n_perturb,
                refine_stalled=np.asarray(stalled)[:k],
                refine_failed=failed_h,
                factor_dtype=np.dtype(eng.factor_dtype).name,
                fallback_mask=np.zeros(k, bool), n_fp64_fallback=0,
                solve_time=time.perf_counter() - t0,
                escalation=(["refine"] if max_iter > 0 else []))
    if max_iter > 0:
        # a NaN/Inf residual or solution must count as failed: the device
        # mask is `resid > tol`, and NaN compares False — without this a
        # numerically singular system's NaN solution would sail through
        # flagged converged (silent garbage instead of an honest failure)
        failed_h = info["refine_failed"] = _nonfinite_failed(x, info)
    if fallback_armed and failed_h.any():
        x = _fp64_redo(bst, b_src, x, info)
        info["escalation"].append("fp64_fallback")
        # the redo's own masks come from the same `> tol` comparison —
        # guard them too in case the fp64 re-solve is still non-finite
        info["refine_failed"] = _nonfinite_failed(x, info)
        info["solve_time"] = time.perf_counter() - t0
    return x, info


def _nonfinite_failed(x: np.ndarray, info: dict) -> np.ndarray:
    """``refine_failed`` with non-finite residuals/solutions OR-ed in:
    per-system (or per system/RHS for a (K, m) residual) True wherever
    the reported mask is set, the residual is NaN/Inf, or the solution
    contains a non-finite entry."""
    failed = np.asarray(info["refine_failed"])
    resid = np.asarray(info["residual"])
    bad = ~np.isfinite(resid)
    x_bad = ~np.isfinite(x.reshape(x.shape[0], -1)).all(axis=1)
    return failed | bad | (x_bad if bad.ndim == 1 else x_bad[:, None])


def _fp64_redo(bst: BatchedFactorState, b_src, x: np.ndarray,
               info: dict) -> np.ndarray:
    """The per-system fp64 escape hatch of :func:`solve_batched`: re-factor
    and re-solve the refinement-failed subset in float64 (one batched call
    at the subset size) and splice the recovered solutions, residuals and
    masks back into the mixed-precision results.  Needs the fp64-staged
    values (``bst.values_batch``) — the reduced-precision factors are
    discarded for these systems."""
    an = bst.analysis
    opts = an.opts
    t0 = time.perf_counter()
    failed_h = info["refine_failed"]
    sys_mask = failed_h if failed_h.ndim == 1 else failed_h.any(axis=1)
    idx = np.nonzero(sys_mask)[0]
    eng64 = jax_repeated_engine(an, dtype=np.float64,
                                refine_dtype=np.float64)
    v_sub = np.ascontiguousarray(
        np.asarray(bst.values_batch, dtype=np.float64)[idx])
    b_sub = np.ascontiguousarray(np.asarray(b_src)[idx])
    v_dev, _, f = _stage_values(eng64, v_sub)
    jf = eng64.refactor_batched(v_dev)
    b_dev = _stage_rhs(eng64, b_sub, f)
    solver = eng64.refined_batched_solver(*bst.a_pattern)
    x64, resid64, _, n_ref64, st64, fl64 = solver(
        jf.vals, jf.inode_perm, v_dev, b_dev, opts.refine_max_iter,
        resolve_refine_tol(opts, "float64"))
    x = np.array(x)                       # jax views are read-only; splice
    x[idx] = np.asarray(x64)[:f].astype(x.dtype)
    for key, new in (("residual", resid64), ("n_refine_per_system", n_ref64),
                     ("refine_stalled", st64), ("refine_failed", fl64)):
        merged = np.array(info[key])
        merged[idx] = np.asarray(new)[:f]
        info[key] = merged
    info["fallback_mask"] = sys_mask
    info["n_fp64_fallback"] = int(len(idx))
    info["fallback_time"] = time.perf_counter() - t0
    return x


def _solve_batched_hostloop(bst: BatchedFactorState, b_batch: np.ndarray,
                            refine: bool | None = None) -> tuple:
    """Pre-fusion reference implementation of :func:`solve_batched`: device
    substitution but numpy residuals and a Python refinement loop (one
    host round-trip per iteration).  Kept as the benchmark baseline the
    fused path is measured against, and as a parity oracle — same
    per-system improved/converged masking, same multi-RHS shapes."""
    import jax.numpy as jnp

    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    # stage/accumulate in the engine's refine dtype, like the fused path
    # (the substitution itself runs in the factor dtype inside apply_batched)
    rdt = np.dtype(eng.refine_dtype)
    tol = resolve_refine_tol(opts, eng.refine_dtype)
    b_batch = np.asarray(b_batch, dtype=rdt)
    if b_batch.ndim == 1:
        b_batch = np.broadcast_to(b_batch, (bst.k, b_batch.shape[0]))

    # the oracle path always runs unsharded at the true batch size: slice
    # any mesh padding off the (possibly sharded) device buffers
    vals_k, inode_k = bst.vals[:bst.k], bst.inode_perm[:bst.k]

    def residuals(x):
        r = b_batch - _batched_matvec(bst.a_pattern, bst.values_batch, x)
        return r, np.abs(r).sum(axis=1) / bnorm

    bnorm = np.abs(b_batch).sum(axis=1)          # (K,) or (K, m)
    bnorm = np.where(bnorm == 0.0, 1.0, bnorm)
    x = np.asarray(eng.apply_batched(vals_k, inode_k,
                                     jnp.asarray(b_batch))).astype(rdt)
    r, resid = residuals(x)
    n_ref = 0
    alive = np.ones(resid.shape, bool)
    max_iter = 0 if refine is False else opts.refine_max_iter
    for _ in range(max_iter):
        need = alive & (resid > tol)
        if not need.any():
            break
        x2 = x + np.asarray(eng.apply_batched(vals_k, inode_k,
                                              jnp.asarray(r))).astype(rdt)
        r2, resid2 = residuals(x2)
        n_ref += 1
        improved = resid2 < resid
        upd = need & improved                     # mirror the fused masking
        x = np.where(upd[:, None], x2, x)
        r = np.where(upd[:, None], r2, r)
        resid = np.where(upd, resid2, resid)
        alive = alive & (improved | ~need)
    failed = (resid > tol) & (max_iter > 0)
    info = dict(residual=resid, n_refine=n_ref, n_perturb=bst.n_perturb,
                refine_failed=failed, refine_stalled=failed & ~alive,
                solve_time=time.perf_counter() - t0)
    return x, info


def _seed_values(values_batch) -> np.ndarray:
    """The (nnz,) float64 host values that seed the analysis: system 0 of
    the (possibly committed-device) batch.  Deliberately float64 whatever
    the engine dtype — the host analysis (MC64 matching/scaling, ordering)
    always runs in full precision; the scale maps are cast down once at
    engine build, not here.  Indexes down to one row *before* the host
    transfer, so a committed (K, nnz) buffer costs one row D2H, not K;
    accepts a list/tuple of value sets, a (K, nnz) batch, or a single
    (nnz,) vector."""
    v0 = values_batch
    while isinstance(v0, (list, tuple)) or getattr(v0, "ndim", 1) > 1:
        v0 = v0[0]
    return np.asarray(v0, dtype=np.float64).copy()


def _is_step_sequence(values_batch) -> bool:
    """True when values_batch is a T-step sequence — a list/tuple of 2-D
    (K, nnz) value sets or a stacked (T, K, nnz) array — rather than one
    batched step.  A list of 1-D (nnz,) value sets keeps its historical
    meaning: ONE batched step of K systems (np.atleast_2d semantics)."""
    if isinstance(values_batch, (list, tuple)):
        if not values_batch:
            return False
        first = values_batch[0]
        ndim = getattr(first, "ndim", None)
        return (np.asarray(first).ndim if ndim is None else ndim) >= 2
    ndim = getattr(values_batch, "ndim", None)
    return ndim == 3


def solve_sequence(a_pattern, values_batch, b_batch,
                   opts: HyluOptions | None = None) -> tuple:
    """Repeated-solve convenience (the paper's §3.2 scenario, batched):
    one analysis, then batched factorizations + solves as pre-compiled
    XLA programs (sharded over the mesh when ``opts.mesh`` is set).

    a_pattern     CSR (or (indptr, indices)) — the shared sparsity pattern
    values_batch  (K, nnz) value sets — ONE batched step — or a T-step
                  sequence ((T, K, nnz) array, or a list of per-step 2-D
                  (K, nnz) arrays, host or committed jax device buffers).
                  A list of 1-D (nnz,) vectors keeps its historical
                  meaning: one batched step of K systems.  The first
                  value set seeds the analysis (matching/ordering are
                  value-dependent but stable across the mild value drift
                  of Newton/transient sequences)
    b_batch       (K, n) right-hand sides, (n,) broadcast, or (K, n, m)
                  multi-RHS (adjoint/sensitivity sweeps); for a step
                  sequence, either one such RHS reused every step or a
                  list/tuple with one entry per step

    For a single step: returns (x (K, n[, m]), info) as before.

    For a T-step sequence the calls run as an **async double-buffered
    pipeline**: while the device factors + solves step t, the host stages
    step t+1's values (``jax.device_put`` overlaps the copy with compute),
    and nothing blocks until the final gather — so H2D staging hides
    behind solves.  With ``opts.donate`` each step additionally recycles
    the previous step's factor buffers (``refactor_batched_reuse``) and
    donates the consumed value/RHS buffers, so a long refactor stream
    runs allocation-flat.  Returns (x (T, K, n[, m]), info) with
    info["residual"] (T, K[, m]) and per-step refinement counts."""
    if _is_step_sequence(values_batch):
        return _solve_sequence_pipelined(a_pattern, values_batch, b_batch,
                                         opts)
    pattern = _pattern_of(a_pattern)
    n = len(pattern[0]) - 1
    a0 = CSR(n, pattern[0], pattern[1], _seed_values(values_batch))
    an = analyze(a0, opts)
    bst = factor_batched(an, pattern, values_batch)
    x, info = solve_batched(bst, b_batch)
    info["timings"] = {"preprocess": an.timings, "factor": bst.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = "jax-batched"
    info["k"] = bst.k
    return x, info


def _solve_sequence_pipelined(a_pattern, values_steps, b_steps,
                              opts: HyluOptions | None = None) -> tuple:
    """The T-step async pipeline behind :func:`solve_sequence`.

    Per step: refactor (optionally donating the previous step's factor
    buffers into the allocation) + the fused refined solve (optionally
    donating the step's A-values/RHS buffers), dispatched asynchronously;
    step t+1's values are staged to device immediately after dispatch so
    the H2D copy overlaps the device's work on step t.  Host↔device
    synchronization happens once, at the end."""
    import jax

    steps_v = (list(values_steps) if isinstance(values_steps, (list, tuple))
               else [values_steps[t] for t in range(values_steps.shape[0])])
    n_steps = len(steps_v)
    pattern = _pattern_of(a_pattern)
    n = len(pattern[0]) - 1

    # per-step RHS must come as a list/tuple (one entry per step, each any
    # single-step shape); a bare array is a single-step RHS reused every
    # step — keeps (K, n, m) multi-RHS unambiguous
    per_step_b = isinstance(b_steps, (list, tuple))
    if per_step_b and len(b_steps) != n_steps:
        raise ValueError(f"got {len(b_steps)} per-step right-hand sides "
                         f"for {n_steps} steps")

    def b_of(t):
        return b_steps[t] if per_step_b else b_steps

    a0 = CSR(n, pattern[0], pattern[1], _seed_values(steps_v[0]))
    an = analyze(a0, opts)
    opts = an.opts
    eng = jax_repeated_engine(an)
    donate = bool(opts.donate)
    solver = eng.refined_batched_solver(*pattern, donate=donate)
    max_iter = opts.refine_max_iter
    tol = resolve_refine_tol(opts, eng.refine_dtype)

    t_all = time.perf_counter()
    # stage step 0 (the analysis already synced the host, so this is cheap);
    # copy=donate: a donated staging buffer must never BE the caller's (or
    # a shared across-steps) committed array — step t+1 restages it
    v_dev, _, k = _stage_values(eng, steps_v[0])
    b_dev = _stage_rhs(eng, b_of(0), k, copy=donate)
    outs, n_pert = [], []
    prev = None
    for t in range(n_steps):
        if donate and prev is not None:
            jf = eng.refactor_batched_reuse(prev.vals, prev.inode_perm,
                                            v_dev)
        else:
            jf = eng.refactor_batched(v_dev)
        x, resid, n_iter, n_ref, stalled, failed = solver(
            jf.vals, jf.inode_perm, v_dev, b_dev, max_iter, tol)
        # stage step t+1 while the device chews on step t — this H2D copy
        # is the one the double-buffering hides
        if t + 1 < n_steps:
            v_dev, _, k2 = _stage_values(eng, steps_v[t + 1])
            if k2 != k:
                raise ValueError(f"step {t + 1} has batch size {k2}, "
                                 f"step 0 had {k}")
            b_dev = _stage_rhs(eng, b_of(t + 1), k, copy=donate)
        outs.append((x, resid, n_iter, n_ref, stalled, failed))
        n_pert.append(jf.n_perturb)
        prev = jf
    jax.block_until_ready(outs[-1][0])           # the single sync point
    t_all = time.perf_counter() - t_all

    x = np.stack([np.asarray(o[0])[:k] for o in outs])
    resid = np.stack([np.asarray(o[1])[:k] for o in outs])
    # the async pipeline reports the failure masks but does not run the
    # fp64 escape hatch (a mid-stream redo would stall the double
    # buffering); single-step solve_batched is the fallback-capable path
    info = dict(residual=resid,
                n_refine=[int(o[2]) for o in outs],
                n_refine_per_system=np.stack(
                    [np.asarray(o[3])[:k] for o in outs]),
                n_perturb=np.stack([np.asarray(p)[:k] for p in n_pert]),
                refine_stalled=np.stack(
                    [np.asarray(o[4])[:k] for o in outs]),
                refine_failed=np.stack(
                    [np.asarray(o[5])[:k] for o in outs]),
                solve_time=t_all,
                timings={"preprocess": an.timings, "pipeline": t_all},
                mode=an.choice.mode, ordering=an.ordering_name,
                engine="jax-batched", k=k, steps=n_steps,
                donate=donate)
    return x, info
