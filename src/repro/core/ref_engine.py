"""Reference numeric engine (numpy, float64).

Executes a FactorPlan exactly as the JAX/Pallas engine does (same panels,
same edge semantics, same pivoting) but in plain vectorized numpy.  Serves
as (a) the correctness oracle for the JAX engine and every Pallas kernel,
and (b) the measurable CPU engine for the paper-figure benchmarks.

The three hybrid kernels appear here as shape specializations of one edge
update (see plan.py): k==1 → row-row / sup-row (divide + axpy/GEMV),
k>1 → sup-sup (TRSM + GEMM).
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .matrix import CSR
from .plan import FactorPlan

__all__ = ["Factors", "SolvePlan", "LevelSched", "factor", "refactor",
           "factor_value_loop", "extract_lu", "build_solve_plan", "solve_lu"]


@dataclasses.dataclass
class Factors:
    plan: FactorPlan
    vals: np.ndarray           # flat panel values
    inode_perm: np.ndarray     # (n,) factored row g holds original row inode_perm[g]
    n_perturb: int
    perturb_eps: float         # relative threshold used (× max|B|)


def _trsm_upper(u: np.ndarray, x: np.ndarray) -> np.ndarray:
    """Solve Y @ U = X for Y, with U (k,k) upper-triangular (non-unit diag).
    Vectorized over the rows of X. k is a supernode width (small)."""
    k = u.shape[0]
    y = np.empty_like(x)
    for j in range(k):
        y[:, j] = (x[:, j] - y[:, :j] @ u[:j, j]) / u[j, j]
    return y


def factor(plan: FactorPlan, b: CSR,
           perturb_eps: float | None = 1e-8) -> Factors:
    """Numeric factorization. b is the preprocessed matrix (scaled, matched,
    reordered); its max |entry| is ~1 after MC64 scaling, so the pivot
    perturbation threshold is perturb_eps * max|B| ≈ perturb_eps.
    ``perturb_eps=None`` (the HyluOptions dtype-aware sentinel) resolves to
    the fp64 literal 1e-8 — this engine is float64-only."""
    if perturb_eps is None:
        perturb_eps = 1e-8
    vals = np.zeros(plan.total_slots, dtype=np.float64)
    vals[plan.a_scatter] = b.data
    amax = float(np.max(np.abs(b.data))) if b.nnz else 1.0
    eps_p = perturb_eps * amax
    inode_perm = np.arange(plan.n, dtype=np.int64)
    n_perturb = 0

    for nd in plan.nodes:
        off = plan.panel_offset[nd.nid]
        nr, w = nd.nr, nd.width
        panel = vals[off:off + nr * w].reshape(nr, w)
        # ---------------- edge updates (ascending source) ------------------
        for e in nd.edges:
            snd = plan.nodes[e.src]
            soff = plan.panel_offset[snd.nid]
            sp = vals[soff:soff + snd.nr * snd.width].reshape(snd.nr, snd.width)
            src = sp[:, snd.lsize:]                    # (k, k+m)
            k = snd.nr
            x = panel[:, e.col_map]                    # gather (nr, k+m)
            if k == 1:
                lts = x[:, :1] / src[0, 0]             # row-row / sup-row
                x = x[:, 1:] - lts * src[:, 1:]
                panel[:, e.col_map[:1]] = lts
                panel[:, e.col_map[1:]] = x
            else:
                lts = _trsm_upper(src[:, :k], x[:, :k])  # sup-sup: TRSM
                xr = x[:, k:] - lts @ src[:, k:]         #          GEMM
                panel[:, e.col_map[:k]] = lts
                panel[:, e.col_map[k:]] = xr
        # ---------------- internal factorization (diag-block pivoting) -----
        ls = nd.lsize
        blk = panel[:, ls:ls + nr]                     # view
        for j in range(nr):
            p = j + int(np.argmax(np.abs(blk[j:, j])))
            if p != j:                                 # supernode diagonal pivoting
                panel[[j, p]] = panel[[p, j]]
                gj, gp = nd.r0 + j, nd.r0 + p
                inode_perm[gj], inode_perm[gp] = inode_perm[gp], inode_perm[gj]
            piv = blk[j, j]
            if abs(piv) < eps_p:                       # pivot perturbation
                piv = eps_p if piv >= 0 else -eps_p
                blk[j, j] = piv
                n_perturb += 1
            if j + 1 < nr:
                l = blk[j + 1:, j] / piv
                blk[j + 1:, j] = l
                panel[j + 1:, ls + j + 1:] -= np.outer(l, panel[j, ls + j + 1:])
        vals[off:off + nr * w] = panel.reshape(-1)
    return Factors(plan=plan, vals=vals, inode_perm=inode_perm,
                   n_perturb=n_perturb, perturb_eps=eps_p)


# --------------------------------------------------------------------------
# L/U extraction (also defines the static solve structure)
# --------------------------------------------------------------------------
def extract_lu(f: Factors) -> tuple[CSR, CSR]:
    """Assemble CSR L (unit diagonal stored) and U from the panels.
    Row/column ids are in the *factored* ordering (panel positions)."""
    plan = f.plan
    lr, lc, lv = [], [], []
    ur, uc, uv = [], [], []
    for nd in plan.nodes:
        off = plan.panel_offset[nd.nid]
        nr, w, ls = nd.nr, nd.width, nd.lsize
        panel = f.vals[off:off + nr * w].reshape(nr, w)
        pat = nd.pattern
        for q in range(nr):
            g = nd.r0 + q
            # L: cols < r0 (panel prefix) + in-block strictly lower + unit diag
            lr.extend([g] * ls); lc.extend(pat[:ls].tolist())
            lv.extend(panel[q, :ls].tolist())
            lr.extend([g] * q); lc.extend(range(nd.r0, nd.r0 + q))
            lv.extend(panel[q, ls:ls + q].tolist())
            lr.append(g); lc.append(g); lv.append(1.0)
            # U: diag + in-block strictly upper + suffix
            cols_u = list(range(g, nd.r0 + nr)) + pat[ls + nr:].tolist()
            vals_u = panel[q, ls + q:].tolist()
            ur.extend([g] * len(cols_u)); uc.extend(cols_u); uv.extend(vals_u)
    n = plan.n
    l = CSR.from_coo(n, lr, lc, lv, sum_dup=False)
    u = CSR.from_coo(n, ur, uc, uv, sum_dup=False)
    return l, u


# --------------------------------------------------------------------------
# refactorization (repeated-solve path): same pattern, new values
# --------------------------------------------------------------------------
def refactor(f: Factors, b_new: CSR) -> Factors:
    """HYLU's repeated-solve optimization: the entire analysis (plan) is
    reused; only the numeric phase runs. b_new must share b's pattern."""
    return factor(f.plan, b_new, perturb_eps=f.perturb_eps)


def factor_value_loop(plan: FactorPlan, pattern: tuple, m_data_batch,
                      perturb_eps: float = 1e-8) -> list:
    """K independent factorizations of one pattern, as a Python loop.

    pattern is (indptr, indices) of the preprocessed matrix M; m_data_batch
    is (K, nnz).  This is the looped-reference baseline that the batched JAX
    path (jax_engine.RepeatedSolveEngine.refactor_batched) is measured
    against, and the parity oracle for its results."""
    indptr, indices = pattern
    return [factor(plan, CSR(plan.n, indptr, indices, np.asarray(d)),
                   perturb_eps=perturb_eps)
            for d in m_data_batch]


# --------------------------------------------------------------------------
# level-scheduled triangular solves (paper §2.3, dual-mode bulk/sequential)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class LevelSched:
    """Flattened per-level schedule for one triangular solve.

    Per level k: rows[k] (the unknowns finalized this level), and the
    flattened dependency lists cols[k]/vals[k]/seg[k] (seg maps each nnz to
    its position within rows[k]).  Wide levels = bulk mode (one vectorized
    gather+bincount per level); narrow levels form the sequential tail —
    the paper's bulk-sequential dual mode."""
    rows: list
    cols: list
    vals: list
    seg: list
    n_bulk: int


@dataclasses.dataclass
class SolvePlan:
    n: int
    l_sched: LevelSched
    u_sched: LevelSched
    u_diag: np.ndarray


def build_solve_plan(f: Factors, bulk_min_width: int = 8) -> SolvePlan:
    l, u = extract_lu(f)
    n = l.n
    # strip unit diag from L
    li, lx, lp = [], [], [0]
    for i in range(n):
        idx, val = l.row(i)
        keep = idx != i
        li.append(idx[keep]); lx.append(val[keep]); lp.append(lp[-1] + keep.sum())
    l_indptr = np.array(lp, dtype=np.int64)
    l_indices = np.concatenate(li) if n else np.empty(0, np.int64)
    l_vals = np.concatenate(lx) if n else np.empty(0)
    # split U diag
    u_diag = np.empty(n)
    ui, ux, up = [], [], [0]
    for i in range(n):
        idx, val = u.row(i)
        dmask = idx == i
        u_diag[i] = val[dmask][0]
        keep = ~dmask
        ui.append(idx[keep]); ux.append(val[keep]); up.append(up[-1] + keep.sum())
    u_indptr = np.array(up, dtype=np.int64)
    u_indices = np.concatenate(ui) if n else np.empty(0, np.int64)
    u_vals = np.concatenate(ux) if n else np.empty(0)

    def sched_of(indptr, indices, vals, reverse=False) -> LevelSched:
        lev = np.zeros(n, dtype=np.int64)
        rng = range(n - 1, -1, -1) if reverse else range(n)
        for i in rng:
            s, e = indptr[i], indptr[i + 1]
            if e > s:
                lev[i] = 1 + lev[indices[s:e]].max()
        nl = int(lev.max()) + 1 if n else 0
        rows_l, cols_l, vals_l, seg_l = [], [], [], []
        n_bulk = 0
        for k in range(nl):
            rows = np.where(lev == k)[0]
            cnt = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
            seg = np.repeat(np.arange(len(rows)), cnt)
            take = np.concatenate([np.arange(indptr[i], indptr[i + 1])
                                   for i in rows]) if cnt.sum() else np.empty(0, np.int64)
            rows_l.append(rows)
            cols_l.append(indices[take])
            vals_l.append(vals[take])
            seg_l.append(seg)
            if len(rows) >= bulk_min_width:
                n_bulk += 1
        return LevelSched(rows_l, cols_l, vals_l, seg_l, n_bulk)

    l_sched = sched_of(l_indptr, l_indices, l_vals)
    u_sched = sched_of(u_indptr, u_indices, u_vals, reverse=True)
    return SolvePlan(n, l_sched, u_sched, u_diag)


def solve_lu(sp: SolvePlan, c: np.ndarray) -> np.ndarray:
    """Solve L U w = c with level-scheduled substitution (one vectorized
    gather + bincount per level — bulk mode; narrow levels are the
    sequential tail, matching the paper's bulk-sequential dual mode)."""
    y = c.astype(np.float64).copy()
    ls = sp.l_sched
    for rows, cols, vals, seg in zip(ls.rows, ls.cols, ls.vals, ls.seg):
        if len(cols):
            acc = np.bincount(seg, weights=vals * y[cols], minlength=len(rows))
            y[rows] -= acc
    w = y
    us = sp.u_sched
    for rows, cols, vals, seg in zip(us.rows, us.cols, us.vals, us.seg):
        if len(cols):
            acc = np.bincount(seg, weights=vals * w[cols], minlength=len(rows))
            w[rows] = (w[rows] - acc) / sp.u_diag[rows]
        else:
            w[rows] = w[rows] / sp.u_diag[rows]
    return w
