"""JAX numeric engine: the TPU execution of a FactorPlan.

The plan is static host data; this module emits a jittable function
``b_data -> (vals, inode_perm, n_perturb)`` that executes the hybrid-kernel
schedule.  Nodes/edges are unrolled at trace time with static index maps —
every gather/scatter index is a compile-time constant, so XLA sees pure
dense ops (the TPU-native expression of the static symbolic structure).

Kernel mapping (HYLU §2.2 → TPU):
  row-row  : k==1, nr==1  — scalar divide + vector axpy (VPU)
  sup-row  : k>1,  nr==1  — TRSV + GEMV against the source panel (VPU/MXU)
  sup-sup  : k>1,  nr>1   — TRSM + GEMM on dense panels (MXU; optionally the
                            Pallas gather-GEMM-scatter kernel)
Internal supernode factorization = dense partially-pivoted LU on the
diagonal block (supernode diagonal pivoting + pivot perturbation).

``use_pallas=True`` routes panel updates through the Pallas kernels in
``repro.kernels`` (interpret mode on CPU; compiled on real TPUs).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import FactorPlan


class JaxFactors(NamedTuple):
    vals: jax.Array          # flat panel values (float64 or float32)
    inode_perm: jax.Array    # (n,) int32
    n_perturb: jax.Array     # () int32


def _trsm_upper_jax(u, x):
    """Solve Y @ U = X (U upper-triangular, non-unit). Unrolled over k
    (supernode widths are small and static)."""
    k = u.shape[0]
    cols = []
    for j in range(k):
        acc = x[:, j]
        if j:
            yj = jnp.stack(cols, axis=1)            # (nr, j)
            acc = acc - yj @ u[:j, j]
        cols.append(acc / u[j, j])
    return jnp.stack(cols, axis=1)


def _panel_lu(panel, nr, lsize, eps_p, use_pallas=False, interpret=True):
    """Dense LU of the diagonal block with partial pivoting within the
    supernode (supernode diagonal pivoting) + pivot perturbation.
    Returns (panel, local_perm, n_perturb)."""
    if use_pallas and nr > 1:
        from repro.kernels.panel import ops as panel_ops
        return panel_ops.panel_lu(panel, nr, lsize, eps_p, interpret=interpret)
    w = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.int32(0)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, lsize + j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        # swap rows j <-> p of the whole panel (and perm)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, lsize + j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, lsize + j].set(piv)
        nper = nper + small.astype(jnp.int32)
        # eliminate below the pivot: cols >= lsize+j (mask), rows > j
        l = panel[:, lsize + j] / piv
        rmask = (rows > j).astype(panel.dtype)
        l = l * rmask
        urow = panel[j, :]
        cmask = (jnp.arange(w) > lsize + j).astype(panel.dtype)
        panel = panel - jnp.outer(l, urow * cmask)
        panel = panel.at[:, lsize + j].set(jnp.where(rows > j, l, panel[:, lsize + j]))
        return panel, perm, nper

    if nr == 1:
        piv = panel[0, lsize]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[0, lsize].set(piv)
        return panel, perm, small.astype(jnp.int32)
    panel, perm, nper = jax.lax.fori_loop(0, nr, body, (panel, perm, nper))
    return panel, perm, nper


def make_factor_fn(plan: FactorPlan, perturb_eps: float = 1e-8,
                   dtype=jnp.float64, use_pallas: bool = False,
                   interpret: bool = True):
    """Emit the jittable numeric factorization for this plan."""
    offs = plan.panel_offset
    nodes = plan.nodes

    def factor_fn(b_data: jax.Array) -> JaxFactors:
        b_data = b_data.astype(dtype)
        amax = jnp.max(jnp.abs(b_data))
        eps_p = perturb_eps * amax
        vals = jnp.zeros((plan.total_slots,), dtype=dtype)
        vals = vals.at[plan.a_scatter].set(b_data)
        inode = jnp.arange(plan.n, dtype=jnp.int32)
        nper = jnp.int32(0)

        for nd in nodes:
            off = int(offs[nd.nid])
            nr, w = nd.nr, nd.width
            panel = jax.lax.dynamic_slice(vals, (off,), (nr * w,)).reshape(nr, w)
            for e in nd.edges:
                snd = nodes[e.src]
                soff = int(offs[snd.nid])
                sp = jax.lax.dynamic_slice(
                    vals, (soff,), (snd.nr * snd.width,)).reshape(snd.nr, snd.width)
                src = sp[:, snd.lsize:]
                k = snd.nr
                cm = e.col_map
                x = panel[:, cm]
                if k == 1:
                    lts = x[:, :1] / src[0, 0]          # row-row / sup-row
                    xr = x[:, 1:] - lts * src[:, 1:]
                else:
                    if use_pallas and nr > 1:
                        from repro.kernels.supsup import ops as supsup_ops
                        lts, xr = supsup_ops.supsup_update(
                            x, src, k, interpret=interpret)
                    else:
                        lts = _trsm_upper_jax(src[:, :k], x[:, :k])
                        xr = x[:, k:] - lts @ src[:, k:]
                panel = panel.at[:, cm].set(jnp.concatenate([lts, xr], axis=1))
            panel, lperm, np_ = _panel_lu(panel, nr, nd.lsize, eps_p,
                                          use_pallas=use_pallas,
                                          interpret=interpret)
            nper = nper + np_
            if nr > 1:
                seg = jax.lax.dynamic_slice(inode, (nd.r0,), (nr,))
                inode = jax.lax.dynamic_update_slice(inode, seg[lperm], (nd.r0,))
            vals = jax.lax.dynamic_update_slice(vals, panel.reshape(-1), (off,))
        return JaxFactors(vals=vals, inode_perm=inode, n_perturb=nper)

    return factor_fn


# --------------------------------------------------------------------------
# level-scheduled triangular solves in JAX (static SolveStructure schedules)
# --------------------------------------------------------------------------
def _tri_solve(sched, vals, rhs, diag_slots=None, transpose_diag=False):
    """One triangular substitution following a TriSched. Each level is one
    vectorized gather + segment-sum (bulk mode); narrow tail levels are tiny
    sequential ops — the paper's bulk-sequential dual mode, unrolled."""
    w = rhs
    for rows, cols, slot, seg in zip(sched.rows, sched.cols, sched.slot,
                                     sched.seg):
        if diag_slots is None:          # unit-diagonal (L or Lᵀ)
            if len(cols):
                acc = jax.ops.segment_sum(vals[slot] * w[cols], seg,
                                          num_segments=len(rows))
                w = w.at[rows].add(-acc)
        else:
            d = vals[diag_slots[rows]]
            if len(cols):
                acc = jax.ops.segment_sum(vals[slot] * w[cols], seg,
                                          num_segments=len(rows))
                w = w.at[rows].set((w[rows] - acc) / d)
            else:
                w = w.at[rows].set(w[rows] / d)
    return w


def make_lu_solver(ss, dtype=jnp.float64):
    """Emit jittable solves on the flat panel buffer:

        lu_solve(vals, c)   = U⁻¹ L⁻¹ c
        lut_solve(vals, c)  = L⁻ᵀ U⁻ᵀ c      (adjoint path)
    """
    def lu_solve(vals, c):
        y = _tri_solve(ss.l_fwd, vals, c.astype(vals.dtype))
        return _tri_solve(ss.u_bwd, vals, y, diag_slots=ss.lu.u_diag_slots)

    def lut_solve(vals, c):
        y = _tri_solve(ss.ut_fwd, vals, c.astype(vals.dtype),
                       diag_slots=ss.lu.u_diag_slots)
        return _tri_solve(ss.lt_bwd, vals, y)

    return lu_solve, lut_solve


# --------------------------------------------------------------------------
# batched repeated-solve path: K factorizations + K solves, one XLA program
# --------------------------------------------------------------------------
def _tri_solve_batched(sched, vals, rhs, diag_slots=None):
    """Batched level-scheduled substitution: vals (K, slots), rhs (K, n).

    Same schedule as ``_tri_solve`` but each level's gather + segment-sum is
    vectorized over the batch as well — one (K, m) product and one
    segment-sum per level for the whole batch, instead of K programs."""
    w = rhs
    for rows, cols, slot, seg in zip(sched.rows, sched.cols, sched.slot,
                                     sched.seg):
        if len(cols):
            prod = vals[:, slot] * w[:, cols]                        # (K, m)
            acc = jax.ops.segment_sum(prod.T, seg,
                                      num_segments=len(rows)).T      # (K, r)
        if diag_slots is None:          # unit-diagonal L
            if len(cols):
                w = w.at[:, rows].add(-acc)
        else:
            d = vals[:, diag_slots[rows]]
            if len(cols):
                w = w.at[:, rows].set((w[:, rows] - acc) / d)
            else:
                w = w.at[:, rows].set(w[:, rows] / d)
    return w


def make_batched_lu_solver(ss, dtype=jnp.float64):
    """Batched variant of :func:`make_lu_solver` over (K, slots)/(K, n)."""
    def lu_solve_batched(vals, c):
        y = _tri_solve_batched(ss.l_fwd, vals, c.astype(vals.dtype))
        return _tri_solve_batched(ss.u_bwd, vals, y,
                                  diag_slots=ss.lu.u_diag_slots)
    return lu_solve_batched


def make_permuted_apply(lu_solve, n, p, q, row_scale, col_scale,
                        dtype=jnp.float64):
    """Compose the full solve A⁻¹ b from LU substitution and the analysis
    transformations (see api.py header):

        apply(vals, inode_perm, b) = s · scatter_q(scatter_p(
                                       U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ))

    Single definition shared by the repeated-solve engine and the
    differentiable solver (autodiff) so the permutation/scaling semantics
    cannot diverge."""
    p_ = jnp.asarray(p)
    q_ = jnp.asarray(q)
    r_ = jnp.asarray(row_scale, dtype=dtype)
    s_ = jnp.asarray(col_scale, dtype=dtype)

    def apply(vals, inode_perm, b):
        c = (r_ * b.astype(dtype))[p_][inode_perm]
        w = lu_solve(vals, c)
        z = jnp.zeros(n, dtype).at[p_].set(w)
        y = jnp.zeros(n, dtype).at[q_].set(z)
        return s_ * y

    return apply


class RepeatedSolveEngine:
    """Pre-compiled repeated-solve engine for one analysis pattern.

    Holds the jitted callables HYLU's repeated-solve scenario needs — the
    analysis is done once on the host, then every (re)factorization and
    substitution is a single pre-compiled XLA call:

      refactor(a_data)                 -> JaxFactors        (one value set)
      refactor_batched(a_batch)        -> JaxFactors, vmapped over K sets
      apply(vals, inode_perm, b)       -> x   solving A x = b with the stored
                                              factors (scales + permutations
                                              + LU substitution fused)
      apply_batched(vals, inode, B)    -> X   (K, n) via the natively batched
                                              level-scheduled tri-solve

    All index maps (scatter/gather, permutations, level schedules) are
    compile-time constants; only values flow through the program, so one
    compilation serves thousands of Newton/time/Monte-Carlo steps.
    """

    def __init__(self, plan: FactorPlan, ss, *, src_map, scale_map, p, q,
                 row_scale, col_scale, perturb_eps: float = 1e-8,
                 dtype=jnp.float64, use_pallas: bool = False,
                 interpret: bool = True):
        if np.dtype(dtype) == np.float64 and not jax.config.jax_enable_x64:
            # without this, float64 silently degrades to float32 and every
            # solve limps through refinement at ~1e-6 residuals
            raise RuntimeError(
                "engine dtype is float64 but jax x64 is disabled — run "
                "jax.config.update('jax_enable_x64', True) before building "
                "the engine, or request dtype=jnp.float32 explicitly")
        self.n = plan.n
        self.dtype = dtype
        factor_fn = make_factor_fn(plan, perturb_eps=perturb_eps, dtype=dtype,
                                   use_pallas=use_pallas, interpret=interpret)
        lu_solve, lut_solve = make_lu_solver(ss, dtype=dtype)
        lu_solve_b = make_batched_lu_solver(ss, dtype=dtype)
        src = jnp.asarray(src_map)
        scl = jnp.asarray(scale_map, dtype=dtype)
        p_ = jnp.asarray(p)
        q_ = jnp.asarray(q)
        r_ = jnp.asarray(row_scale, dtype=dtype)
        s_ = jnp.asarray(col_scale, dtype=dtype)
        n = self.n

        def _refactor(a_data):
            # A.data -> M.data is a pure gather+scale (see api.analyze)
            return factor_fn(a_data.astype(dtype)[src] * scl)

        _apply = make_permuted_apply(lu_solve, n, p, q, row_scale, col_scale,
                                     dtype=dtype)

        def _apply_batched(vals, inode_perm, b):
            c = (r_ * b.astype(dtype))[:, p_]
            c = jnp.take_along_axis(c, inode_perm, axis=1)
            w = lu_solve_b(vals, c)
            z = jnp.zeros_like(w).at[:, p_].set(w)
            y = jnp.zeros_like(z).at[:, q_].set(z)
            return s_ * y

        self.refactor = jax.jit(_refactor)
        self.refactor_batched = jax.jit(jax.vmap(_refactor))
        self.apply = jax.jit(_apply)
        self.apply_batched = jax.jit(_apply_batched)
        self.lut_solve = jax.jit(lut_solve)
