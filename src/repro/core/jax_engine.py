"""JAX numeric engine: the TPU execution of a FactorPlan.

The plan is static host data; this module emits a jittable function
``b_data -> (vals, inode_perm, n_perturb)`` that executes the hybrid-kernel
schedule.  Nodes/edges are unrolled at trace time with static index maps —
every gather/scatter index is a compile-time constant, so XLA sees pure
dense ops (the TPU-native expression of the static symbolic structure).

Kernel mapping (HYLU §2.2 → TPU):
  row-row  : k==1, nr==1  — scalar divide + vector axpy (VPU)
  sup-row  : k>1,  nr==1  — TRSV + GEMV against the source panel (VPU/MXU)
  sup-sup  : k>1,  nr>1   — TRSM + GEMM on dense panels (MXU; optionally the
                            Pallas gather-GEMM-scatter kernel)
Internal supernode factorization = dense partially-pivoted LU on the
diagonal block (supernode diagonal pivoting + pivot perturbation).

``use_pallas=True`` routes panel updates through the Pallas kernels in
``repro.kernels`` (interpret mode on CPU; compiled on real TPUs).
"""
from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import FactorPlan


def _jit_donating(fn, donate_argnums):
    """jax.jit with donate_argnums, silencing the 'donated buffers were not
    usable' warning: the A-values buffer intentionally has no same-shaped
    output to alias — its donation is an early-free hint, not a bug."""
    jitted = jax.jit(fn, donate_argnums=donate_argnums)

    def call(*args):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jitted(*args)

    return call


class JaxFactors(NamedTuple):
    vals: jax.Array          # flat panel values (float64 or float32)
    inode_perm: jax.Array    # (n,) int32
    n_perturb: jax.Array     # () int32


def _trsm_upper_jax(u, x):
    """Solve Y @ U = X (U upper-triangular, non-unit). Unrolled over k
    (supernode widths are small and static)."""
    k = u.shape[0]
    cols = []
    for j in range(k):
        acc = x[:, j]
        if j:
            yj = jnp.stack(cols, axis=1)            # (nr, j)
            acc = acc - yj @ u[:j, j]
        cols.append(acc / u[j, j])
    return jnp.stack(cols, axis=1)


def _panel_lu(panel, nr, lsize, eps_p, use_pallas=False, interpret=True):
    """Dense LU of the diagonal block with partial pivoting within the
    supernode (supernode diagonal pivoting) + pivot perturbation.
    Returns (panel, local_perm, n_perturb)."""
    if use_pallas and nr > 1:
        from repro.kernels.panel import ops as panel_ops
        return panel_ops.panel_lu(panel, nr, lsize, eps_p, interpret=interpret)
    w = panel.shape[1]
    perm = jnp.arange(nr, dtype=jnp.int32)
    nper = jnp.int32(0)

    def body(j, carry):
        panel, perm, nper = carry
        col = jax.lax.dynamic_slice_in_dim(panel, lsize + j, 1, axis=1)[:, 0]
        rows = jnp.arange(nr)
        cand = jnp.where(rows >= j, jnp.abs(col), -1.0)
        p = jnp.argmax(cand)
        # swap rows j <-> p of the whole panel (and perm)
        swap = jnp.arange(nr).at[j].set(p).at[p].set(j)
        panel = panel[swap, :]
        perm = perm[swap]
        piv = panel[j, lsize + j]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[j, lsize + j].set(piv)
        nper = nper + small.astype(jnp.int32)
        # eliminate below the pivot: cols >= lsize+j (mask), rows > j
        l = panel[:, lsize + j] / piv
        rmask = (rows > j).astype(panel.dtype)
        l = l * rmask
        urow = panel[j, :]
        cmask = (jnp.arange(w) > lsize + j).astype(panel.dtype)
        panel = panel - jnp.outer(l, urow * cmask)
        panel = panel.at[:, lsize + j].set(jnp.where(rows > j, l, panel[:, lsize + j]))
        return panel, perm, nper

    if nr == 1:
        piv = panel[0, lsize]
        small = jnp.abs(piv) < eps_p
        piv = jnp.where(small, jnp.where(piv >= 0, eps_p, -eps_p), piv)
        panel = panel.at[0, lsize].set(piv)
        return panel, perm, small.astype(jnp.int32)
    panel, perm, nper = jax.lax.fori_loop(0, nr, body, (panel, perm, nper))
    return panel, perm, nper


def _node_lu_writeback(vals, inode, nper, nd, panel, off, eps_p,
                       use_pallas, interpret):
    """Internal LU of one node's (already edge-updated) panel + pivot
    bookkeeping + write-back.  Shared by the fully unrolled trace and the
    bucketed trace's narrow-level sequential nodes (whose edges were
    applied eagerly, so they need exactly this edge-free remainder);
    ``vals``/``inode`` may carry extra sentinel slots past the plan's
    sizes — all offsets touched here are real."""
    nr = nd.nr
    panel, lperm, np_ = _panel_lu(panel, nr, nd.lsize, eps_p,
                                  use_pallas=use_pallas, interpret=interpret)
    nper = nper + np_
    if nr > 1:
        seg = jax.lax.dynamic_slice(inode, (nd.r0,), (nr,))
        inode = jax.lax.dynamic_update_slice(inode, seg[lperm], (nd.r0,))
    vals = jax.lax.dynamic_update_slice(vals, panel.reshape(-1), (off,))
    return vals, inode, nper


def _node_step_unrolled(vals, inode, nper, nd, nodes, offs, eps_p,
                        use_pallas, interpret):
    """One node's left-looking edge loop + internal LU (the per-node
    sequential kernel of the unrolled trace)."""
    off = int(offs[nd.nid])
    nr, w = nd.nr, nd.width
    panel = jax.lax.dynamic_slice(vals, (off,), (nr * w,)).reshape(nr, w)
    for e in nd.edges:
        snd = nodes[e.src]
        soff = int(offs[snd.nid])
        sp = jax.lax.dynamic_slice(
            vals, (soff,), (snd.nr * snd.width,)).reshape(snd.nr, snd.width)
        src = sp[:, snd.lsize:]
        k = snd.nr
        cm = e.col_map
        x = panel[:, cm]
        if k == 1:
            lts = x[:, :1] / src[0, 0]          # row-row / sup-row
            xr = x[:, 1:] - lts * src[:, 1:]
        else:
            if use_pallas and nr > 1:
                from repro.kernels.supsup import ops as supsup_ops
                lts, xr = supsup_ops.supsup_update(
                    x, src, k, interpret=interpret)
            else:
                lts = _trsm_upper_jax(src[:, :k], x[:, :k])
                xr = x[:, k:] - lts @ src[:, k:]
        panel = panel.at[:, cm].set(jnp.concatenate([lts, xr], axis=1))
    return _node_lu_writeback(vals, inode, nper, nd, panel, off, eps_p,
                              use_pallas, interpret)


def _panel_lu_bucketed(panels, wu, eps_p, use_pallas=False, interpret=True):
    """Dense LU with in-block partial pivoting on a (B, nr, wt) bucket of
    column-reordered panels: elimination runs over the static window
    [0, wu) (block + U suffix); trailing columns (the L prefix) only get
    row-permuted.  Padded block diagonals are identity so padded pivot
    steps are exact no-ops.  Returns (panels, perm (B, nr), nper (B,))."""
    if use_pallas:
        from repro.kernels.panel import ops as panel_ops
        return panel_ops.panel_lu_batched(panels, wu, eps_p,
                                          interpret=interpret)
    from repro.kernels.panel.ref import panel_lu_bucketed_ref
    return panel_lu_bucketed_ref(panels, wu, eps_p)


def _make_factor_fn_bucketed(plan: FactorPlan, perturb_eps, dtype,
                             use_pallas, interpret, bulk_min_width=8):
    """Level-bucketed trace: O(levels × shape-buckets) XLA ops instead of
    O(nodes + edges).  Every level's edge applications run as batched
    per-bucket gathers + TRSM / GEMM + scatters; internal LUs are bucketed
    on wide levels (the paper's bulk mode, on the factor path) and
    per-node on narrow levels (sequential mode)."""
    from .structure import get_bucket_schedule

    sched = get_bucket_schedule(plan, bulk_min_width=bulk_min_width)
    nodes = plan.nodes
    offs = plan.panel_offset

    def factor_fn(b_data: jax.Array) -> JaxFactors:
        b_data = b_data.astype(dtype)
        amax = jnp.max(jnp.abs(b_data))
        eps_p = perturb_eps * amax
        vals = jnp.zeros((sched.n_ext,), dtype=dtype)
        vals = vals.at[plan.a_scatter].set(b_data)
        # identity-pivot sentinel: a huge value rather than 1.0, so padded
        # diagonals can never test as "small" even under absurd
        # perturb_eps settings (|1e30| < eps_p is false for any sane eps;
        # padded TRSM/divide still yields exact zeros: 0 / 1e30 == 0)
        vals = vals.at[sched.one_slot].set(jnp.asarray(1e30, dtype))
        inode = jnp.arange(plan.n + 1, dtype=jnp.int32)
        nper = jnp.int32(0)

        for step in sched.steps:
            # ---- internal factorization of this level's nodes ------------
            if step.diag is not None:           # width-1: perturb diagonals
                dsl = jnp.asarray(step.diag.slots)
                d = vals[dsl]
                small = jnp.abs(d) < eps_p
                d = jnp.where(small, jnp.where(d >= 0, eps_p, -eps_p), d)
                vals = vals.at[dsl].set(d)
                nper = nper + jnp.sum(small).astype(jnp.int32)
            for pb in step.panels:              # wider: bucketed dense LU
                P = vals[jnp.asarray(pb.gather)]
                P, perm, npb = _panel_lu_bucketed(
                    P, pb.wu, eps_p, use_pallas=use_pallas,
                    interpret=interpret)
                vals = vals.at[jnp.asarray(pb.scatter)].set(P)
                nper = nper + jnp.sum(npb).astype(jnp.int32)
                rows = jnp.asarray(pb.rows)
                seg = inode[rows]
                inode = inode.at[rows].set(
                    jnp.take_along_axis(seg, perm, axis=1))
            for t in step.seq:                  # narrow level: per-node LU
                nd = nodes[int(t)]
                off = int(offs[nd.nid])
                panel = jax.lax.dynamic_slice(
                    vals, (off,), (nd.nr * nd.width,)).reshape(nd.nr,
                                                               nd.width)
                vals, inode, nper = _node_lu_writeback(
                    vals, inode, nper, nd, panel, off, eps_p,
                    use_pallas, interpret)
            # ---- eager application of this level's outgoing edges --------
            for eb in step.edges:
                S = vals[jnp.asarray(eb.src_idx)]     # (E, k, k+m)
                U, Us = S[:, :, :eb.k], S[:, :, eb.k:]
                X = vals[jnp.asarray(eb.x_idx)]       # (E, nr, k)
                if eb.k == 1:                         # row-row / sup-row
                    lts = X / U[:, 0, 0][:, None, None]
                    delta = lts * Us                  # (E, nr, 1)·(E, 1, m)
                elif use_pallas:                      # sup-sup on Pallas
                    from repro.kernels.supsup import ops as supsup_ops
                    from repro.kernels.trisolve import ops as trisolve_ops
                    lts = trisolve_ops.trsm_batched(U, X, interpret=interpret)
                    delta = supsup_ops.gemm_batched(lts, Us,
                                                    interpret=interpret)
                else:                                 # sup-sup via XLA
                    lts = jax.lax.linalg.triangular_solve(
                        U, X, left_side=False, lower=False)
                    delta = lts @ Us
                # one combined scatter: multiplier write-back expressed as
                # an add of (lts - X), trailing update as -delta
                ne = lts.shape[0]
                w_vals = jnp.concatenate([(lts - X).reshape(ne, -1),
                                          (-delta).reshape(ne, -1)], axis=1)
                vals = vals.at[jnp.asarray(eb.write_idx)].add(w_vals)

        # ---- scanned width-1 suffix: one traced body per chunk -----------
        def scan_body(carry, xs):
            vals, nper = carry
            dsl, x_i, s_i, w_i = xs
            d = vals[dsl]
            small = jnp.abs(d) < eps_p          # pads read the huge sentinel
            d = jnp.where(small, jnp.where(d >= 0, eps_p, -eps_p), d)
            vals = vals.at[dsl].set(d)
            nper = nper + jnp.sum(small).astype(jnp.int32)
            S = vals[s_i]                       # (E, 1+M)
            X = vals[x_i]                       # (E,)
            lts = X / S[:, 0]
            upd = jnp.concatenate([(lts - X)[:, None],
                                   -lts[:, None] * S[:, 1:]], axis=1)
            vals = vals.at[w_i].add(upd)
            return (vals, nper), None

        for ch in sched.scan_chunks:
            (vals, nper), _ = jax.lax.scan(
                scan_body, (vals, nper),
                (jnp.asarray(ch.dsl), jnp.asarray(ch.x_idx),
                 jnp.asarray(ch.src_idx), jnp.asarray(ch.write_idx)))

        return JaxFactors(vals=vals[:plan.total_slots],
                          inode_perm=inode[:plan.n], n_perturb=nper)

    return factor_fn


def make_factor_fn(plan: FactorPlan, perturb_eps: float = 1e-8,
                   dtype=jnp.float64, use_pallas: bool = False,
                   interpret: bool = True, schedule: str = "bucketed",
                   bulk_min_width: int = 8):
    """Emit the jittable numeric factorization for this plan.

    schedule="bucketed" (default) traces the level-bucketed program —
    O(levels × shape-buckets) ops, the only way compile time stays sane
    past toy sizes; "unrolled" keeps the historical per-node/per-edge
    trace (parity oracle for the bucketed path, and micro-best for very
    small plans)."""
    if schedule == "bucketed":
        return _make_factor_fn_bucketed(plan, perturb_eps, dtype,
                                        use_pallas, interpret,
                                        bulk_min_width=bulk_min_width)
    if schedule != "unrolled":
        raise ValueError(f"unknown factor schedule {schedule!r}: "
                         "expected 'bucketed' or 'unrolled'")
    offs = plan.panel_offset
    nodes = plan.nodes

    def factor_fn(b_data: jax.Array) -> JaxFactors:
        b_data = b_data.astype(dtype)
        amax = jnp.max(jnp.abs(b_data))
        eps_p = perturb_eps * amax
        vals = jnp.zeros((plan.total_slots,), dtype=dtype)
        vals = vals.at[plan.a_scatter].set(b_data)
        inode = jnp.arange(plan.n, dtype=jnp.int32)
        nper = jnp.int32(0)
        for nd in nodes:
            vals, inode, nper = _node_step_unrolled(
                vals, inode, nper, nd, nodes, offs, eps_p,
                use_pallas, interpret)
        return JaxFactors(vals=vals, inode_perm=inode, n_perturb=nper)

    return factor_fn


# --------------------------------------------------------------------------
# level-scheduled triangular solves in JAX (static SolveStructure schedules)
# --------------------------------------------------------------------------
def _tri_scan_chunks(sched, n, bulk_min_width: int = 8):
    """Chunked scan schedule for a TriSched's narrow tail levels.

    The trace of a level-unrolled substitution is O(levels); the long
    narrow tail of a sparse triangular schedule makes that expensive to
    compile for zero runtime benefit.  This packs maximal runs of
    consecutive narrow levels — padded to shared (rows, deps) shapes with
    at most 4x waste per dim — into per-chunk index arrays a single
    ``lax.scan`` body consumes.  Padding is maskless: padded rows/cols
    point at the extra row n of the padded unknown vector (which provably
    stays 0), padded slots at slot 0 (multiplied by that 0).

    Returns (n_head_levels, [(rows, rowmap, cols, slot), ...]); cached on
    the TriSched keyed by ``bulk_min_width``."""
    cache = getattr(sched, "_scan_chunks", None)
    if cache is None:
        cache = {}
        sched._scan_chunks = cache
    cached = cache.get(bulk_min_width)
    if cached is not None:
        return cached
    from .structure import segment_levels

    levels = list(zip(sched.rows, sched.cols, sched.slot, sched.seg))
    s = len(levels)
    while s > 0 and len(levels[s - 1][0]) < bulk_min_width:
        s -= 1

    groups = [levels[s + i:s + j]
              for i, j in segment_levels(
                  [(len(l[0]), len(l[1])) for l in levels[s:]])]

    chunks = []
    for group in groups:
        rmax = max(max(len(g[0]) for g in group), 1)
        dmax = max(max(len(g[1]) for g in group), 1)
        nl = len(group)
        rows_a = np.full((nl, rmax), n, np.int64)
        rowmap_a = np.full((nl, dmax), n, np.int64)
        cols_a = np.full((nl, dmax), n, np.int64)
        slot_a = np.zeros((nl, dmax), np.int64)
        for l, (r, c, sl, sg) in enumerate(group):
            rows_a[l, :len(r)] = r
            if len(sg):
                rowmap_a[l, :len(sg)] = r[sg]
            cols_a[l, :len(c)] = c
            slot_a[l, :len(sl)] = sl
        chunks.append((rows_a, rowmap_a, cols_a, slot_a))
    cached = (s, chunks)
    cache[bulk_min_width] = cached
    return cached


def _tri_solve(sched, vals, rhs, diag_slots=None, transpose_diag=False):
    """One triangular substitution following a TriSched.  Each bulk level
    is one vectorized gather + scatter-add; the narrow tail levels run as
    chunked ``lax.scan``s (see ``_tri_scan_chunks``) — the paper's
    bulk-sequential dual mode with an O(bulk levels + chunks) trace.  The
    per-row reduction and the row update fold into a single
    duplicate-accumulating scatter (rows[seg] maps every dependency
    straight to its target row) — scatter op count is what XLA compile
    time scales with."""
    n = rhs.shape[0]
    n_head, chunks = _tri_scan_chunks(sched, n)
    w = rhs
    for rows, cols, slot, seg in zip(sched.rows[:n_head],
                                     sched.cols[:n_head],
                                     sched.slot[:n_head],
                                     sched.seg[:n_head]):
        if diag_slots is None:          # unit-diagonal (L or Lᵀ)
            if len(cols):
                w = w.at[rows[seg]].add(-(vals[slot] * w[cols]))
        else:                           # non-unit diagonal U
            if len(cols):
                w = w.at[rows[seg]].add(-(vals[slot] * w[cols]))
            w = w.at[rows].divide(vals[diag_slots[rows]])
    if chunks:
        if diag_slots is not None:
            dpad = jnp.asarray(np.concatenate([diag_slots, diag_slots[:1]]))
        w = jnp.concatenate([w, jnp.zeros((1,), w.dtype)])

        def body(w, xs):
            rows_l, rowmap_l, cols_l, slot_l = xs
            w = w.at[rowmap_l].add(-(vals[slot_l] * w[cols_l]))
            if diag_slots is not None:
                w = w.at[rows_l].divide(vals[dpad[rows_l]])
            return w, None

        for ch in chunks:
            w, _ = jax.lax.scan(body, w, tuple(jnp.asarray(a) for a in ch))
        w = w[:n]
    return w


def make_lu_solver(ss, dtype=jnp.float64):
    """Emit jittable solves on the flat panel buffer:

        lu_solve(vals, c)   = U⁻¹ L⁻¹ c
        lut_solve(vals, c)  = L⁻ᵀ U⁻ᵀ c      (adjoint path)
    """
    def lu_solve(vals, c):
        y = _tri_solve(ss.l_fwd, vals, c.astype(vals.dtype))
        return _tri_solve(ss.u_bwd, vals, y, diag_slots=ss.lu.u_diag_slots)

    def lut_solve(vals, c):
        y = _tri_solve(ss.ut_fwd, vals, c.astype(vals.dtype),
                       diag_slots=ss.lu.u_diag_slots)
        return _tri_solve(ss.lt_bwd, vals, y)

    return lu_solve, lut_solve


# --------------------------------------------------------------------------
# batched repeated-solve path: K factorizations + K solves, one XLA program
# --------------------------------------------------------------------------
def _tri_solve_batched(sched, vals, rhs, diag_slots=None):
    """Batched level-scheduled substitution: vals (K, slots), rhs (K, n) or
    (K, n, m) for multi-RHS.

    Same schedule as ``_tri_solve`` — bulk levels unrolled (one product and
    one duplicate-index scatter-add per level), narrow tail levels as
    chunked ``lax.scan``s — with every op vectorized over the batch (and
    any trailing RHS dim) as well.  Everything stays in the batch-first
    layout: the reduction is a scatter-add on axis 1, not a segment-sum,
    so no per-level ``moveaxis`` round-trips materialize (K, nnz)
    transposes."""
    n = rhs.shape[1]
    n_head, chunks = _tri_scan_chunks(sched, n)
    w = rhs
    multi = w.ndim == 3
    for rows, cols, slot, seg in zip(sched.rows[:n_head],
                                     sched.cols[:n_head],
                                     sched.slot[:n_head],
                                     sched.seg[:n_head]):
        if len(cols):
            v = vals[:, slot]
            prod = v[:, :, None] * w[:, cols] if multi else v * w[:, cols]
        if diag_slots is None:          # unit-diagonal L
            if len(cols):               # one fused scatter: deps → rows
                w = w.at[:, rows[seg]].add(-prod)
        else:                           # non-unit diagonal U
            d = vals[:, diag_slots[rows]]
            if multi:
                d = d[:, :, None]
            if len(cols):
                w = w.at[:, rows[seg]].add(-prod)
            w = w.at[:, rows].divide(d)
    if chunks:
        if diag_slots is not None:
            dpad = jnp.asarray(np.concatenate([diag_slots, diag_slots[:1]]))
        w = jnp.concatenate(
            [w, jnp.zeros(w.shape[:1] + (1,) + w.shape[2:], w.dtype)],
            axis=1)

        def body(w, xs):
            rows_l, rowmap_l, cols_l, slot_l = xs
            v = vals[:, slot_l]
            prod = v[:, :, None] * w[:, cols_l] if multi else v * w[:, cols_l]
            w = w.at[:, rowmap_l].add(-prod)
            if diag_slots is not None:
                d = vals[:, dpad[rows_l]]
                if multi:
                    d = d[:, :, None]
                w = w.at[:, rows_l].divide(d)
            return w, None

        for ch in chunks:
            w, _ = jax.lax.scan(body, w, tuple(jnp.asarray(a) for a in ch))
        w = w[:, :n]
    return w


def _block_lu_solve_batched(blocks, vals, c, interpret=True):
    """Batched L U w = c following the node-block schedule: per node one
    dense GEMV against the L-prefix/U-suffix rectangle plus a dense
    triangular solve of the diagonal block — routed through the Pallas TRSM
    (``kernels/trisolve``) for supernodes.  This is the ``use_pallas``
    substitution path; width-1 nodes degenerate to scalar ops."""
    from repro.kernels.trisolve import ops as trisolve_ops

    multi = c.ndim == 3
    w = c if multi else c[..., None]
    for nd in blocks:                               # forward: unit-lower L
        b_blk = w[:, nd.r0:nd.r0 + nd.nr]
        if nd.pre_cols.size:
            b_blk = b_blk - jnp.einsum("kns,ksm->knm",
                                       vals[:, nd.pre_slots],
                                       w[:, nd.pre_cols])
        if nd.nr > 1:
            b_blk = trisolve_ops.trsm_left_unit_lower_batched(
                vals[:, nd.blk_slots], b_blk, interpret=interpret)
        w = w.at[:, nd.r0:nd.r0 + nd.nr].set(b_blk)
    for nd in reversed(blocks):                     # backward: upper U
        b_blk = w[:, nd.r0:nd.r0 + nd.nr]
        if nd.suf_cols.size:
            b_blk = b_blk - jnp.einsum("kns,ksm->knm",
                                       vals[:, nd.suf_slots],
                                       w[:, nd.suf_cols])
        blk = vals[:, nd.blk_slots]
        if nd.nr > 1:
            b_blk = trisolve_ops.trsm_left_upper_batched(
                blk, b_blk, interpret=interpret)
        else:
            b_blk = b_blk / blk[:, :, 0:1]
        w = w.at[:, nd.r0:nd.r0 + nd.nr].set(b_blk)
    return w if multi else w[..., 0]


def make_batched_lu_solver(ss, dtype=jnp.float64, use_pallas: bool = False,
                           interpret: bool = True):
    """Batched variant of :func:`make_lu_solver` over (K, slots)/(K, n)
    (or (K, n, m) multi-RHS).  ``use_pallas=True`` swaps the level-scheduled
    scatter-add substitution for the node-block schedule whose supernode
    diagonal blocks run on the Pallas TRSM kernel."""
    if use_pallas:
        def lu_solve_batched(vals, c):
            return _block_lu_solve_batched(ss.blocks, vals,
                                           c.astype(vals.dtype),
                                           interpret=interpret)
        return lu_solve_batched

    def lu_solve_batched(vals, c):
        y = _tri_solve_batched(ss.l_fwd, vals, c.astype(vals.dtype))
        return _tri_solve_batched(ss.u_bwd, vals, y,
                                  diag_slots=ss.lu.u_diag_slots)
    return lu_solve_batched


def make_csr_matvec_batched(indptr, indices):
    """Device-side batched CSR matvec with the pattern baked in as
    compile-time constants: ``(A_k x_k)`` for K matrices sharing one
    sparsity pattern, x (K, n) or (K, n, m).

    One gather + one batch-first scatter-add for the whole batch (no
    per-call transposes of the (K, nnz) product); empty rows stay exact
    zeros (no host fallback), and the batch dtype is preserved.  This is
    the residual matvec of the fused refinement loop — it keeps
    r = b - A x on device."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    n = len(indptr) - 1
    seg = jnp.asarray(np.repeat(np.arange(n), np.diff(indptr)))
    idx = jnp.asarray(indices)

    def matvec(a_vals, x):
        prod = (a_vals[:, :, None] * x[:, idx] if x.ndim == 3
                else a_vals * x[:, idx])
        return jnp.zeros((x.shape[0], n) + x.shape[2:],
                         prod.dtype).at[:, seg].add(prod)

    return matvec


def _output_perm(p, q):
    """The solve's two output scatters z[p]=w, y[q]=z composed into one
    static gather index:  z[p]=w ⇒ z=w[p⁻¹];  y[q]=z ⇒ y=z[q⁻¹];  hence
    y = w[p⁻¹[q⁻¹]].  Shared by the scalar and batched apply paths so the
    permutation semantics cannot diverge."""
    return jnp.asarray(np.argsort(p)[np.argsort(q)])


def make_permuted_apply(lu_solve, n, p, q, row_scale, col_scale,
                        dtype=jnp.float64):
    """Compose the full solve A⁻¹ b from LU substitution and the analysis
    transformations (see api.py header):

        apply(vals, inode_perm, b) = s · scatter_q(scatter_p(
                                       U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ))

    Single definition shared by the repeated-solve engine and the
    differentiable solver (autodiff) so the permutation/scaling semantics
    cannot diverge.  The two output scatters z[p]=w, y[q]=z compose into
    one static gather (y = w[p⁻¹∘q⁻¹] — permutation inverses are known at
    analysis time), which is both faster and far cheaper to compile."""
    p_ = jnp.asarray(p)
    out_perm = _output_perm(p, q)
    r_ = jnp.asarray(row_scale, dtype=dtype)
    s_ = jnp.asarray(col_scale, dtype=dtype)

    def apply(vals, inode_perm, b):
        c = (r_ * b.astype(dtype))[p_][inode_perm]
        w = lu_solve(vals, c)
        return s_ * w[out_perm]

    return apply


class RepeatedSolveEngine:
    """Pre-compiled repeated-solve engine for one analysis pattern.

    Holds the jitted callables HYLU's repeated-solve scenario needs — the
    analysis is done once on the host, then every (re)factorization and
    substitution is a single pre-compiled XLA call:

      refactor(a_data)                 -> JaxFactors        (one value set)
      refactor_batched(a_batch)        -> JaxFactors, vmapped over K sets
                                              (shard_mapped over the mesh's
                                              system-batch axis when the
                                              engine was built with one)
      refactor_batched_reuse(prev, a)  -> same, donating the previous step's
                                              JaxFactors buffers so a
                                              refactor *stream* reuses its
                                              allocations instead of growing
      apply(vals, inode_perm, b)       -> x   solving A x = b with the stored
                                              factors (scales + permutations
                                              + LU substitution fused)
      apply_batched(vals, inode, B)    -> X   (K, n) — or (K, n, m) for
                                              multi-RHS — via the natively
                                              batched tri-solve (scatter-add
                                              levels + scanned narrow tail,
                                              or the Pallas-TRSM node-block
                                              path when ``use_pallas=True``);
                                              always single-device (it is the
                                              host-loop oracle path)
      refined_batched_solver(ip, ix)   -> the *fused* batched solve:
                                              substitution + device CSR
                                              residual matvec + the whole
                                              iterative-refinement loop as
                                              ONE jitted XLA program
                                              (lax.while_loop; zero host
                                              transfers per iteration)

    All index maps (scatter/gather, permutations, level schedules) are
    compile-time constants; only values flow through the program, so one
    compilation serves thousands of Newton/time/Monte-Carlo steps.

    Sharding (``mesh`` not None): the batched programs are wrapped in
    ``shard_map`` over the mesh's single axis — each device runs the
    *identical* per-system program on its K/D shard of the batch, and no
    collective touches the numerics (only the refinement iteration count is
    ``pmax``-reduced for reporting), so sharded results are bit-identical
    to the single-device path.  Callers pad K to a multiple of the device
    count (api.factor_batched does this; padded systems ride the same
    per-system ``alive`` masking the refinement loop already carries).
    """

    def __init__(self, plan: FactorPlan, ss, *, src_map, scale_map, p, q,
                 row_scale, col_scale, perturb_eps: float = 1e-8,
                 dtype=jnp.float64, refine_dtype=None,
                 use_pallas: bool = False,
                 interpret: bool = True, schedule: str = "bucketed",
                 bulk_min_width: int = 8, mesh=None):
        if refine_dtype is None:
            # mirror options.resolve_dtype_names: residual/solution
            # accumulation (and A-value/RHS staging) happen in fp64 whenever
            # x64 is available — a reduced factor dtype then still recovers
            # fp64-accurate solutions through refinement
            refine_dtype = (jnp.float64 if jax.config.jax_enable_x64
                            else dtype)
        for role, dt in (("factor", dtype), ("refine", refine_dtype)):
            if np.dtype(dt) == np.float64 and not jax.config.jax_enable_x64:
                # without this, float64 silently degrades to float32 and
                # every solve limps through refinement at ~1e-6 residuals
                raise RuntimeError(
                    f"engine {role} dtype is float64 but jax x64 is "
                    "disabled — run jax.config.update('jax_enable_x64', "
                    "True) before building the engine, or request "
                    "dtype=jnp.float32 explicitly")
        self.n = plan.n
        self.dtype = dtype             # factor-panel/substitution dtype
        self.factor_dtype = dtype
        self.refine_dtype = refine_dtype
        #: dtype batched A-values/RHS must be staged in (the residual matvec
        #: runs against these, so they carry the refine precision)
        self.values_dtype = refine_dtype
        self.plan = plan
        self.bulk_min_width = bulk_min_width
        factor_fn = make_factor_fn(plan, perturb_eps=perturb_eps, dtype=dtype,
                                   use_pallas=use_pallas, interpret=interpret,
                                   schedule=schedule,
                                   bulk_min_width=bulk_min_width)
        lu_solve, lut_solve = make_lu_solver(ss, dtype=dtype)
        lu_solve_b = make_batched_lu_solver(ss, dtype=dtype,
                                            use_pallas=use_pallas,
                                            interpret=interpret)
        src = jnp.asarray(src_map)
        scl = jnp.asarray(scale_map, dtype=dtype)
        p_ = jnp.asarray(p)
        out_perm = _output_perm(p, q)
        r_ = jnp.asarray(row_scale, dtype=dtype)
        s_ = jnp.asarray(col_scale, dtype=dtype)
        n = self.n

        def _refactor(a_data):
            # A.data -> M.data is a pure gather+scale (see api.analyze)
            return factor_fn(a_data.astype(dtype)[src] * scl)

        _apply = make_permuted_apply(lu_solve, n, p, q, row_scale, col_scale,
                                     dtype=dtype)

        def _apply_batched(vals, inode_perm, b):
            multi = b.ndim == 3                    # (K, n, m) multi-RHS
            c = (b.astype(dtype) * (r_[:, None] if multi else r_))[:, p_]
            idx = inode_perm[:, :, None] if multi else inode_perm
            c = jnp.take_along_axis(c, idx, axis=1)
            w = lu_solve_b(vals, c)
            # z[p]=w; y[q]=z composed into one static gather (see
            # make_permuted_apply)
            y = w[:, out_perm]
            return y * (s_[:, None] if multi else s_)

        self._apply_batched_impl = _apply_batched
        self.mesh = mesh
        self.batch_axis = mesh.axis_names[0] if mesh is not None else None
        self.n_shards = int(mesh.size) if mesh is not None else 1
        refactor_b = jax.vmap(_refactor)
        if mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import NamedSharding, PartitionSpec

            spec = PartitionSpec(self.batch_axis)
            #: the sharding batched inputs should be staged with (device_put
            #: here = no resharding inside the jitted calls)
            self.batch_sharding = NamedSharding(mesh, spec)
            # check_rep=False: the factor/while-loop primitives have no
            # replication rule on this jax version; nothing here is
            # replicated anyway (every output is batch-sharded)
            refactor_b = shard_map(
                refactor_b, mesh=mesh, in_specs=(spec,),
                out_specs=JaxFactors(vals=spec, inode_perm=spec,
                                     n_perturb=spec),
                check_rep=False)
        else:
            self.batch_sharding = None
        self._refactor_batched_impl = refactor_b

        def _refactor_reuse(prev_vals, prev_inode, a_batch):
            # numerically identical to refactor_batched; the prev buffers
            # exist only to be donated, so the output JaxFactors alias them
            # (n_perturb is tiny and stays live for reporting — not donated)
            del prev_vals, prev_inode
            return refactor_b(a_batch)

        self.refactor = jax.jit(_refactor)
        self.refactor_batched = jax.jit(refactor_b)
        self.refactor_batched_reuse = _jit_donating(_refactor_reuse,
                                                    donate_argnums=(0, 1))
        self.apply = jax.jit(_apply)
        self.apply_batched = jax.jit(_apply_batched)
        self.lut_solve = jax.jit(lut_solve)
        self._refined_cache: dict = {}

    def memory_stats(self, k: int = 1) -> dict:
        """Plan-derived byte accounting of this engine at system-batch
        size ``k``, with the engine's actual dtype width (see
        :func:`repro.core.plan.memory_stats`)."""
        from .plan import memory_stats
        return memory_stats(self.plan, bulk_min_width=self.bulk_min_width,
                            k=k, dtype_bytes=np.dtype(self.dtype).itemsize)

    def refined_batched_solver(self, indptr, indices, donate: bool = False):
        """The fused batched solve for K systems sharing the given original-A
        pattern (compile-time constants).  Returns a jitted

            solver(vals, inode_perm, a_vals, b, max_iter, tol)
                -> (x, resid, n_iter, n_ref_sys, stalled, failed)

        that runs substitution, the batched CSR residual matvec and the full
        iterative-refinement loop as ONE XLA program: a ``lax.while_loop``
        carries ``(x, r, resid, alive, ...)`` with per-system improved /
        converged masking, so no per-iteration host transfer happens.
        Substitution runs in the engine's factor dtype; b/a_vals/x/residual
        are carried in ``refine_dtype`` (stage them in ``values_dtype``).

        b is (K, n) or (K, n, m) multi-RHS; resid / n_ref_sys / stalled /
        failed are (K,) or (K, m) accordingly (1-norm residuals relative to
        each RHS column).  A system (or RHS column) stops refining once its
        residual is at or below ``tol`` or an iteration fails to improve it
        — the same acceptance rule as the scalar host path.  ``failed``
        marks systems that exited above ``tol`` (the fp64-fallback trigger);
        ``stalled`` marks the subset that stopped improving rather than
        running out of iterations.  ``max_iter=0`` disables refinement
        (refine=False; both masks are all-False then).

        With an engine mesh, the program is shard_mapped over the batch
        axis: each device runs its own refinement loop on its shard (the
        per-system masking makes per-shard loop lengths invisible in x),
        and ``n_iter`` is the pmax across shards.  ``donate=True`` builds a
        variant that donates the A-values and RHS buffers — the
        sequence-pipeline mode where each step's inputs die with the step
        (factor buffers are recycled separately via
        ``refactor_batched_reuse``); the state passed in is consumed."""
        indptr = np.asarray(indptr)
        indices = np.asarray(indices)
        key = (indptr.tobytes(), indices.tobytes(), bool(donate))
        solver = self._refined_cache.get(key)
        if solver is not None:
            return solver

        matvec = make_csr_matvec_batched(indptr, indices)
        apply_b = self._apply_batched_impl
        rdtype = self.refine_dtype
        batch_axis = self.batch_axis

        def solve_refined(vals, inode_perm, a_vals, b, max_iter, tol):
            multi = b.ndim == 3
            # mixed precision: substitution runs in the factor dtype
            # (apply_b casts its RHS down internally), while b, the
            # A-values, the solution and the residual are carried in the
            # refine dtype — the residual must be computed against the
            # original-precision A or the recoverable accuracy is capped
            # at eps(factor_dtype)
            b = b.astype(rdtype)
            a_vals = a_vals.astype(rdtype)
            bnorm = jnp.sum(jnp.abs(b), axis=1)              # (K,) | (K, m)
            bnorm = jnp.where(bnorm == 0.0, 1.0, bnorm)

            def expand(m):                 # mask (K,)|(K,m) -> broadcast to b
                return m[:, None, :] if multi else m[:, None]

            # the base solve is iteration 0 of the loop (x=0, r=b,
            # resid=inf), so the substitution pipeline is traced — and
            # compiled — exactly once instead of once outside and once in
            # the loop body; the iterate sequence is unchanged
            # (0 + A⁻¹b ≡ the old explicit base solve).
            x = jnp.zeros_like(b)
            r = b
            resid = jnp.full(bnorm.shape, jnp.inf, rdtype)
            alive = jnp.ones(resid.shape, bool)
            n_ref = jnp.zeros(resid.shape, jnp.int32)

            def cond(carry):
                _, _, resid, alive, _, it = carry
                return (it < max_iter + 1) & jnp.any(alive & (resid > tol))

            def body(carry):
                x, r, resid, alive, n_ref, it = carry
                need = alive & (resid > tol)
                x2 = x + apply_b(vals, inode_perm, r).astype(rdtype)
                r2 = b - matvec(a_vals, x2)
                resid2 = jnp.sum(jnp.abs(r2), axis=1) / bnorm
                # iteration 0 IS the base solve: accepted unconditionally
                # (like the old explicit pre-loop solve), so a NaN/inf base
                # residual surfaces in x instead of masking back to 0
                improved = (resid2 < resid) | (it == 0)
                upd = need & improved
                x = jnp.where(expand(upd), x2, x)
                r = jnp.where(expand(upd), r2, r)
                resid = jnp.where(upd, resid2, resid)
                alive = alive & (improved | ~need)
                n_ref = n_ref + (upd & (it > 0))     # iteration 0 ≡ solve
                return x, r, resid, alive, n_ref, it + 1

            x, r, resid, alive, n_ref, it = jax.lax.while_loop(
                cond, body, (x, r, resid, alive, n_ref, jnp.int32(0)))
            n_iter = jnp.maximum(it - 1, 0)
            if batch_axis is not None:
                # per-shard loops stop independently; report the global
                # iteration count (the only cross-device op in the engine,
                # and it never feeds back into x)
                n_iter = jax.lax.pmax(n_iter, batch_axis)
            # per-system verdicts (meaningful only when refinement ran):
            # failed = exited above tol; stalled = failed because an
            # iteration stopped improving (vs. ran out of iterations) —
            # the escape-hatch signal for the fp64 fallback path
            ran = jnp.int32(max_iter) > 0
            failed = (resid > tol) & ran
            stalled = failed & ~alive
            return x, resid, n_iter, n_ref, stalled, failed

        fn = solve_refined
        if self.mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec

            spec = PartitionSpec(batch_axis)
            rep = PartitionSpec()
            # check_rep=False: lax.while_loop has no replication rule on
            # this jax version; n_iter is the one P() output and the pmax
            # above makes it genuinely replicated
            fn = shard_map(fn, mesh=self.mesh,
                           in_specs=(spec, spec, spec, spec, rep, rep),
                           out_specs=(spec, spec, rep, spec, spec, spec),
                           check_rep=False)
        solver = (_jit_donating(fn, donate_argnums=(2, 3)) if donate
                  else jax.jit(fn))
        self._refined_cache[key] = solver
        return solver
