"""HYLU public API: analyze → factor → solve (+ refactor for repeated solve).

Pipeline (paper §2):
  preprocessing   = MC64 matching/scaling + ordering selection + symbolic
                    factorization + kernel selection + plan build
  numeric         = hybrid-kernel factorization (ref_engine / jax_engine)
  solve           = level-scheduled substitution + iterative refinement

Transformations bookkeeping:  with Dr=diag(r), Ds=diag(s) from matching,
column permutation q (matched entry → diagonal), symmetric ordering p and
the numeric in-node pivot permutation g↦inode_perm[g]:

    M = (P_p (Dr A Ds) Q_q P_pᵀ),     L U = M[inode_perm, :]

    A x = b   ⇒   w = U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ;  z[p]=w ; y[q]=z ; x = s·y
"""
from __future__ import annotations

import dataclasses
import time
import numpy as np

from .matrix import CSR
from .matching import max_weight_matching, apply_static_pivoting, MatchResult
from .ordering import select_ordering
from .kernel_select import select_kernel, KernelChoice
from .plan import build_plan, FactorPlan, plan_stats
from .symbolic import Symbolic, symbolic_stats
from . import ref_engine
from .ref_engine import Factors, SolvePlan


@dataclasses.dataclass
class HyluOptions:
    force_mode: str | None = None          # rowrow | hybrid | supernodal
    orderings: tuple = ("min_degree", "nested_dissection", "natural")
    relax: int = 8
    max_super: int = 128
    perturb_eps: float = 1e-8
    refine_max_iter: int = 3
    refine_tol: float = 1e-12
    bulk_min_width: int = 8


@dataclasses.dataclass
class Analysis:
    n: int
    opts: HyluOptions
    match: MatchResult
    q: np.ndarray              # column permutation from matching
    p: np.ndarray              # fill-reducing ordering
    ordering_name: str
    choice: KernelChoice
    sym: Symbolic
    plan: FactorPlan
    # refactor fast path: M.data = A.data[src_map] * scale_map
    src_map: np.ndarray
    scale_map: np.ndarray
    m_pattern: tuple           # (indptr, indices) of M
    timings: dict


@dataclasses.dataclass
class FactorState:
    analysis: Analysis
    factors: Factors
    solve_plan: SolvePlan
    a: CSR                     # the matrix these factors correspond to
    timings: dict


def analyze(a: CSR, opts: HyluOptions | None = None, reuse=None) -> Analysis:
    """Preprocessing phase (HYLU §2.1).

    reuse: a prior Analysis of the *same matrix* — matching and ordering are
    mode-independent and are reused (benchmarking different kernel modes
    re-runs only symbolic + plan)."""
    opts = opts or HyluOptions()
    t: dict[str, float] = {}
    t0 = time.perf_counter()
    match = reuse.match if reuse is not None else max_weight_matching(a)
    t["matching"] = time.perf_counter() - t0

    # permute/scale with index-tracking data so refactor is a pure gather
    t0 = time.perf_counter()
    seg = np.repeat(np.arange(a.n), np.diff(a.indptr))
    scale_entry = match.row_scale[seg] * match.col_scale[a.indices]
    tracker = CSR(a.n, a.indptr.copy(), a.indices.copy(),
                  np.arange(a.nnz, dtype=np.float64))
    q = match.col_of_row.copy()
    b2_track = tracker.permute(np.arange(a.n), q)

    pat2 = CSR(a.n, b2_track.indptr, b2_track.indices,
               np.ones(a.nnz)).sym_pattern()
    if reuse is not None:
        p, ord_name = reuse.p, reuse.ordering_name
    else:
        p, ord_name = select_ordering(pat2, candidates=opts.orderings)
    t["ordering"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m_track = b2_track.permute(p, p)
    src_map = m_track.data.astype(np.int64)
    scale_map = scale_entry[src_map]
    pat_m = pat2.permute(p, p)
    choice, sym = select_kernel(pat_m, force_mode=opts.force_mode,
                                relax=opts.relax, max_super=opts.max_super)
    t["symbolic"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m = CSR(a.n, m_track.indptr, m_track.indices, np.ones(a.nnz))
    plan = build_plan(pat_m, m, sym, mode=choice.mode,
                      bulk_min_width=opts.bulk_min_width)
    t["plan"] = time.perf_counter() - t0
    t["total"] = sum(t.values())

    return Analysis(n=a.n, opts=opts, match=match, q=q, p=p,
                    ordering_name=ord_name, choice=choice, sym=sym, plan=plan,
                    src_map=src_map, scale_map=scale_map,
                    m_pattern=(m_track.indptr, m_track.indices), timings=t)


def _m_values(an: Analysis, a: CSR) -> CSR:
    data = a.data[an.src_map] * an.scale_map
    return CSR(a.n, an.m_pattern[0], an.m_pattern[1], data)


def factor(an: Analysis, a: CSR, engine=ref_engine) -> FactorState:
    """Numeric factorization + solve-plan build."""
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a)
    f = engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a, timings=t)


def refactor(st: FactorState, a_new: CSR) -> FactorState:
    """Repeated-solve path: same pattern, new values; reuses the analysis
    AND the solve plan's structure (values refresh only)."""
    an = st.analysis
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a_new)
    f = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a_new, timings=t)


def solve(st: FactorState, b: np.ndarray, refine: bool | None = None) -> tuple:
    """Forward/backward substitution + iterative refinement (auto when pivot
    perturbation occurred, per paper §2.3). Returns (x, info)."""
    an, f = st.analysis, st.factors
    opts = an.opts
    t0 = time.perf_counter()

    def lu_apply(rhs: np.ndarray) -> np.ndarray:
        c = (an.match.row_scale * rhs)[an.p][f.inode_perm]
        w = ref_engine.solve_lu(st.solve_plan, c)
        z = np.empty_like(w); z[an.p] = w
        y = np.empty_like(z); y[an.q] = z
        return an.match.col_scale * y

    x = lu_apply(b)
    n_ref = 0
    bnorm = float(np.abs(b).sum()) or 1.0
    resid = float(np.abs(b - st.a.matvec(x)).sum()) / bnorm
    # auto-refine when pivot perturbation occurred (paper §2.3) or the
    # residual is above the target
    do_refine = refine if refine is not None else (
        f.n_perturb > 0 or resid > opts.refine_tol)
    if do_refine:
        for _ in range(opts.refine_max_iter):
            if resid <= opts.refine_tol:
                break
            r = b - st.a.matvec(x)
            x2 = x + lu_apply(r)
            resid2 = float(np.abs(b - st.a.matvec(x2)).sum()) / bnorm
            n_ref += 1
            if resid2 >= resid:
                break
            x, resid = x2, resid2
    info = dict(residual=resid, n_refine=n_ref, n_perturb=f.n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def solve_system(a: CSR, b: np.ndarray, opts: HyluOptions | None = None):
    """One-call convenience: analyze + factor + solve."""
    an = analyze(a, opts)
    st = factor(an, a)
    x, info = solve(st, b)
    info["timings"] = {"preprocess": an.timings, "factor": st.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    return x, info
