"""HYLU public API: analyze → factor → solve (+ refactor for repeated solve).

Pipeline (paper §2):
  preprocessing   = MC64 matching/scaling + ordering selection + symbolic
                    factorization + kernel selection + plan build
  numeric         = hybrid-kernel factorization (ref_engine / jax_engine)
  solve           = level-scheduled substitution + iterative refinement

Transformations bookkeeping:  with Dr=diag(r), Ds=diag(s) from matching,
column permutation q (matched entry → diagonal), symmetric ordering p and
the numeric in-node pivot permutation g↦inode_perm[g]:

    M = (P_p (Dr A Ds) Q_q P_pᵀ),     L U = M[inode_perm, :]

    A x = b   ⇒   w = U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ;  z[p]=w ; y[q]=z ; x = s·y

The batched repeated-solve path (factor_batched / solve_batched /
solve_sequence) lifts the numeric phase over K value sets of one pattern
as single pre-compiled XLA programs, optionally sharded across devices
over the system-batch axis (HyluOptions.mesh) with an async
double-buffered, buffer-donating sequence pipeline (HyluOptions.donate).
Full contracts: docs/API.md; architecture: docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import dataclasses
import time
import numpy as np

from .matrix import CSR
from .matching import max_weight_matching, MatchResult
from .ordering import select_ordering
from .kernel_select import select_kernel, KernelChoice
from .plan import build_plan, FactorPlan
from .symbolic import Symbolic
from . import ref_engine
from .ref_engine import Factors, SolvePlan


@dataclasses.dataclass
class HyluOptions:
    """Solver options — every knob of the analyze/factor/solve pipeline.
    Field-by-field documentation lives in docs/API.md (kept in sync by the
    docs-lint CI step)."""
    force_mode: str | None = None          # rowrow | hybrid | supernodal
    orderings: tuple = ("min_degree", "nested_dissection", "natural")
    relax: int = 8
    max_super: int = 128
    perturb_eps: float = 1e-8
    refine_max_iter: int = 3
    refine_tol: float = 1e-12
    bulk_min_width: int = 8
    engine: str = "ref"                    # ref | jax — default numeric engine
    use_pallas: bool = False               # route jax panel updates via Pallas
    factor_schedule: str = "bucketed"      # bucketed (O(levels) trace) |
                                           # unrolled (O(nodes+edges) oracle)
    mesh: object = None                    # shard the batched path over the
                                           # system-batch axis K: None (single
                                           # device) | int (first N devices,
                                           # launch.mesh.make_solver_mesh) |
                                           # a 1-D jax.sharding.Mesh
    donate: bool = False                   # sequence pipeline donates value/
                                           # RHS/factor buffers step-to-step
                                           # (consumed states; no realloc)


@dataclasses.dataclass
class Analysis:
    """The reusable product of :func:`analyze` (HYLU §2.1): matching,
    ordering, symbolic structure, the static FactorPlan, and the refactor
    gather maps — everything value-independent about one sparsity pattern.
    Also carries the per-pattern cache of compiled jax engines, so keep it
    alive across refactor/solve streams."""
    n: int
    opts: HyluOptions
    match: MatchResult
    q: np.ndarray              # column permutation from matching
    p: np.ndarray              # fill-reducing ordering
    ordering_name: str
    choice: KernelChoice
    sym: Symbolic
    plan: FactorPlan
    # refactor fast path: M.data = A.data[src_map] * scale_map
    src_map: np.ndarray
    scale_map: np.ndarray
    m_pattern: tuple           # (indptr, indices) of M
    timings: dict
    # jit cache keyed on this analysis' plan: (dtype name, use_pallas) →
    # jax_engine.RepeatedSolveEngine (built lazily on first jax-engine use)
    jit_cache: dict = dataclasses.field(default_factory=dict, repr=False)


@dataclasses.dataclass
class FactorState:
    """One numeric factorization of one value set — what :func:`solve`
    consumes and :func:`refactor` refreshes (ref engine: numpy factors +
    solve plan; jax engine: device JaxFactors)."""
    analysis: Analysis
    factors: Factors | None
    solve_plan: SolvePlan | None
    a: CSR                     # the matrix these factors correspond to
    timings: dict
    engine: str = "ref"
    jax_factors: object = None  # jax_engine.JaxFactors when engine == "jax"


@dataclasses.dataclass
class BatchedFactorState:
    """K factorizations of one sparsity pattern (K value sets), held as
    stacked device arrays — the state of the batched repeated-solve path.

    Under a mesh (``HyluOptions.mesh``) the device arrays are padded from K
    up to ``k_pad`` (a multiple of the device count) and sharded over the
    mesh's system-batch axis; ``k`` is always the caller's true batch size
    and every result is sliced back to it."""
    analysis: Analysis
    a_pattern: tuple           # (indptr, indices) of the original matrices
    values_dev: object         # jax (K_pad, nnz) A values on device (fused
                               # residuals — staged once, not per solve)
    vals: object               # jax (K_pad, total_slots) factored panels
    inode_perm: object         # jax (K_pad, n) in-node pivot permutations
    n_perturb: np.ndarray      # (K,) perturbation counts
    timings: dict
    k: int                     # true batch size (≤ k_pad)
    consumed: bool = False     # buffers donated away by solve_batched(
                               # donate=True) — the state is spent
    _values_host: np.ndarray | None = dataclasses.field(default=None,
                                                        repr=False)

    @property
    def k_pad(self) -> int:
        return int(self.vals.shape[0])

    @property
    def values_batch(self) -> np.ndarray:
        """(K, nnz) host mirror of the A values — the oracle the host-loop
        baseline and tests diff against.  Materialized lazily: when the
        caller committed device buffers (no host copy ever existed), the
        first access is one device→host transfer."""
        if self._values_host is None:
            self._values_host = np.asarray(self.values_dev)[:self.k]
        return self._values_host


def analyze(a: CSR, opts: HyluOptions | None = None, reuse=None) -> Analysis:
    """Preprocessing phase (HYLU §2.1).

    reuse: a prior Analysis of the *same matrix* — matching and ordering are
    mode-independent and are reused (benchmarking different kernel modes
    re-runs only symbolic + plan)."""
    opts = opts or HyluOptions()
    t: dict[str, float] = {}
    t0 = time.perf_counter()
    match = reuse.match if reuse is not None else max_weight_matching(a)
    t["matching"] = time.perf_counter() - t0

    # permute/scale with index-tracking data so refactor is a pure gather
    t0 = time.perf_counter()
    seg = np.repeat(np.arange(a.n), np.diff(a.indptr))
    scale_entry = match.row_scale[seg] * match.col_scale[a.indices]
    tracker = CSR(a.n, a.indptr.copy(), a.indices.copy(),
                  np.arange(a.nnz, dtype=np.float64))
    q = match.col_of_row.copy()
    b2_track = tracker.permute(np.arange(a.n), q)

    pat2 = CSR(a.n, b2_track.indptr, b2_track.indices,
               np.ones(a.nnz)).sym_pattern()
    if reuse is not None:
        p, ord_name = reuse.p, reuse.ordering_name
    else:
        p, ord_name = select_ordering(pat2, candidates=opts.orderings)
    t["ordering"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m_track = b2_track.permute(p, p)
    src_map = m_track.data.astype(np.int64)
    scale_map = scale_entry[src_map]
    pat_m = pat2.permute(p, p)
    choice, sym = select_kernel(pat_m, force_mode=opts.force_mode,
                                relax=opts.relax, max_super=opts.max_super)
    t["symbolic"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m = CSR(a.n, m_track.indptr, m_track.indices, np.ones(a.nnz))
    plan = build_plan(pat_m, m, sym, mode=choice.mode,
                      bulk_min_width=opts.bulk_min_width)
    t["plan"] = time.perf_counter() - t0
    t["total"] = sum(t.values())

    return Analysis(n=a.n, opts=opts, match=match, q=q, p=p,
                    ordering_name=ord_name, choice=choice, sym=sym, plan=plan,
                    src_map=src_map, scale_map=scale_map,
                    m_pattern=(m_track.indptr, m_track.indices), timings=t)


def _m_values(an: Analysis, a: CSR) -> CSR:
    data = a.data[an.src_map] * an.scale_map
    return CSR(a.n, an.m_pattern[0], an.m_pattern[1], data)


def _resolve_mesh(mesh):
    """HyluOptions.mesh → a 1-D jax Mesh (or None for the unsharded path):
    None passes through, an int N builds launch.mesh.make_solver_mesh(N),
    a Mesh is validated to one axis."""
    if mesh is None:
        return None
    if isinstance(mesh, (int, np.integer)):
        from repro.launch.mesh import make_solver_mesh
        return make_solver_mesh(int(mesh))
    if not hasattr(mesh, "axis_names"):
        raise TypeError(f"mesh must be None, an int device count, or a "
                        f"jax.sharding.Mesh — got {type(mesh).__name__}")
    if len(mesh.axis_names) != 1:
        raise ValueError("the batched solver shards over one system-batch "
                         f"axis; got a {len(mesh.axis_names)}-D mesh "
                         f"{mesh.axis_names}")
    return mesh


def _mesh_cache_key(mesh):
    """Hashable identity of a resolved mesh for the per-analysis jit cache:
    same devices + axis name ⇒ same compiled programs."""
    if mesh is None:
        return None
    return (mesh.axis_names[0],
            tuple(d.id for d in mesh.devices.flat))


def jax_repeated_engine(an: Analysis, dtype=None, use_pallas: bool | None = None,
                        schedule: str | None = None, mesh=None):
    """The pre-compiled repeated-solve engine for this analysis.

    Built lazily and cached on the analysis (keyed by dtype/pallas/factor
    schedule/mesh devices), so every subsequent factor/refactor/solve
    through ``engine="jax"`` — and every batched call — is one
    already-compiled XLA program.  ``mesh`` (default ``an.opts.mesh``)
    shards the *batched* programs over the system-batch axis; the scalar
    refactor/apply programs are always single-device."""
    import jax.numpy as jnp

    from .jax_engine import RepeatedSolveEngine
    from .structure import build_solve_structure

    dtype = jnp.float64 if dtype is None else dtype
    use_pallas = an.opts.use_pallas if use_pallas is None else use_pallas
    schedule = an.opts.factor_schedule if schedule is None else schedule
    mesh = _resolve_mesh(an.opts.mesh if mesh is None else mesh)
    key = (np.dtype(dtype).name, bool(use_pallas), schedule,
           _mesh_cache_key(mesh))
    eng = an.jit_cache.get(key)
    if eng is None:
        ss = build_solve_structure(an.plan,
                                   bulk_min_width=an.opts.bulk_min_width)
        eng = RepeatedSolveEngine(
            an.plan, ss, src_map=an.src_map, scale_map=an.scale_map,
            p=an.p, q=an.q, row_scale=an.match.row_scale,
            col_scale=an.match.col_scale, perturb_eps=an.opts.perturb_eps,
            dtype=dtype, use_pallas=use_pallas, schedule=schedule,
            bulk_min_width=an.opts.bulk_min_width, mesh=mesh)
        an.jit_cache[key] = eng
    return eng


def _factor_jax(an: Analysis, a: CSR) -> FactorState:
    import jax
    import jax.numpy as jnp

    eng = jax_repeated_engine(an)
    t = {}
    t0 = time.perf_counter()
    jf = eng.refactor(jnp.asarray(a.data))
    jax.block_until_ready(jf.vals)
    t["factor"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=None, solve_plan=None, a=a,
                       timings=t, engine="jax", jax_factors=jf)


def factor(an: Analysis, a: CSR, engine=None) -> FactorState:
    """Numeric factorization + solve-plan build.

    engine: "ref" (numpy), "jax" (pre-compiled XLA; solve structure is
    static so no per-factor solve-plan rebuild), a ref-compatible engine
    module, or None → an.opts.engine."""
    engine = an.opts.engine if engine is None else engine
    if engine == "jax":
        return _factor_jax(an, a)
    if engine == "ref":
        mod = ref_engine
    elif hasattr(engine, "factor"):
        mod = engine
    else:
        raise ValueError(f"unknown engine {engine!r}: expected 'ref', 'jax', "
                         "or an engine module with a factor() function")
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a)
    f = mod.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a, timings=t)


def refactor(st: FactorState, a_new: CSR) -> FactorState:
    """Repeated-solve path: same pattern, new values; reuses the analysis
    AND the solve plan's structure (values refresh only).  On the jax
    engine this is a single pre-compiled ``a_data -> factors`` call."""
    an = st.analysis
    if st.engine == "jax":
        return _factor_jax(an, a_new)
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a_new)
    f = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a_new, timings=t)


def solve(st: FactorState, b: np.ndarray, refine: bool | None = None) -> tuple:
    """Forward/backward substitution + iterative refinement (auto when pivot
    perturbation occurred, per paper §2.3). Returns (x, info)."""
    an = st.analysis
    opts = an.opts
    t0 = time.perf_counter()

    if st.engine == "jax":
        import jax.numpy as jnp

        eng = jax_repeated_engine(an)
        jf = st.jax_factors
        n_perturb = int(jf.n_perturb)

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            return np.asarray(eng.apply(jf.vals, jf.inode_perm,
                                        jnp.asarray(rhs)))
    else:
        f = st.factors
        n_perturb = f.n_perturb

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            c = (an.match.row_scale * rhs)[an.p][f.inode_perm]
            w = ref_engine.solve_lu(st.solve_plan, c)
            z = np.empty_like(w); z[an.p] = w
            y = np.empty_like(z); y[an.q] = z
            return an.match.col_scale * y

    x = lu_apply(b)
    n_ref = 0
    bnorm = float(np.abs(b).sum()) or 1.0
    resid = float(np.abs(b - st.a.matvec(x)).sum()) / bnorm
    # auto-refine when pivot perturbation occurred (paper §2.3) or the
    # residual is above the target
    do_refine = refine if refine is not None else (
        n_perturb > 0 or resid > opts.refine_tol)
    if do_refine:
        for _ in range(opts.refine_max_iter):
            if resid <= opts.refine_tol:
                break
            r = b - st.a.matvec(x)
            x2 = x + lu_apply(r)
            resid2 = float(np.abs(b - st.a.matvec(x2)).sum()) / bnorm
            n_ref += 1
            if resid2 >= resid:
                break
            x, resid = x2, resid2
    info = dict(residual=resid, n_refine=n_ref, n_perturb=n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def solve_system(a: CSR, b: np.ndarray, opts: HyluOptions | None = None):
    """One-call convenience: analyze + factor + solve."""
    an = analyze(a, opts)
    st = factor(an, a)
    x, info = solve(st, b)
    info["timings"] = {"preprocess": an.timings, "factor": st.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = st.engine
    return x, info


# --------------------------------------------------------------------------
# batched repeated solve: K value sets of one pattern as one XLA program
# --------------------------------------------------------------------------
def _pattern_of(a_pattern) -> tuple:
    if isinstance(a_pattern, CSR):
        return (a_pattern.indptr, a_pattern.indices)
    indptr, indices = a_pattern
    return (np.asarray(indptr), np.asarray(indices))


def _batched_matvec(pattern: tuple, values_batch: np.ndarray,
                    x_batch: np.ndarray) -> np.ndarray:
    """(A_k x_k) for K CSR matrices sharing one pattern: one gather +
    row-segment reduction for the whole batch.

    Host-side (numpy) reference: the production jax path computes residuals
    with the device matvec baked into the fused solver
    (``jax_engine.make_csr_matvec_batched``); this stays as the oracle for
    tests and as the host-loop benchmark baseline.  x_batch is (K, n) or
    (K, n, m) multi-RHS."""
    indptr, indices = pattern
    if x_batch.ndim == 3:
        prod = values_batch[:, :, None] * x_batch[:, indices]
    else:
        prod = values_batch * x_batch[:, indices]
    counts = np.diff(indptr)
    if len(counts) == 0:
        return np.zeros_like(x_batch)
    if counts.min() > 0:
        return np.add.reduceat(prod, indptr[:-1], axis=1)
    # reduceat mishandles empty rows; fall back to per-batch scatter-add
    # (preserves the batch dtype, unlike bincount which promotes to float64)
    seg = np.repeat(np.arange(len(counts)), counts)
    out = np.zeros((x_batch.shape[0], len(counts)) + x_batch.shape[2:],
                   dtype=prod.dtype)
    for k in range(out.shape[0]):
        np.add.at(out[k], seg, prod[k])
    return out


def _pad_k(eng, k: int) -> int:
    """K padded up to a multiple of the engine's shard count."""
    return -(-k // eng.n_shards) * eng.n_shards


def _stage_values(eng, values_batch):
    """Stage a (K, nnz) value set on device for the batched engine.

    Honors committed device buffers: a jax array input is used in place —
    no device→host→device round-trip (the pre-sharding code always pulled
    values through numpy).  K is padded to a multiple of the mesh device
    count by replicating system 0 (well-conditioned; padded systems are
    masked out of every result), and the buffer is placed with the
    engine's batch sharding.  Returns ``(values_dev (K_pad, nnz),
    values_host | None, k)`` — ``values_host`` is the (K, nnz) float64
    oracle when the input came from the host, else None (materialized
    lazily by ``BatchedFactorState.values_batch``)."""
    import jax
    import jax.numpy as jnp

    if isinstance(values_batch, jax.Array):
        v = values_batch if values_batch.ndim > 1 else values_batch[None]
        host = None
        k = int(v.shape[0])
        k_pad = _pad_k(eng, k)
        if k_pad != k:
            v = jnp.concatenate(
                [v, jnp.broadcast_to(v[:1], (k_pad - k, v.shape[1]))])
    else:
        host = np.ascontiguousarray(
            np.atleast_2d(np.asarray(values_batch, dtype=np.float64)))
        k = host.shape[0]
        k_pad = _pad_k(eng, k)
        v = host if k_pad == k else np.concatenate(
            [host, np.broadcast_to(host[:1], (k_pad - k, host.shape[1]))])
    if eng.batch_sharding is not None:
        v = jax.device_put(v, eng.batch_sharding)
    elif not isinstance(v, jax.Array):
        v = jnp.asarray(v)
    return v, host, k


def _stage_rhs(eng, b_batch, k: int, copy: bool = False):
    """Stage right-hand sides (K, n) / (n,) broadcast / (K, n, m) on device:
    same device-buffer honoring, zero-padding of K to the mesh multiple
    (zero RHS ⇒ the padded systems converge on iteration 0), and batch
    sharding placement.  A leading dimension that matches neither K nor 1
    raises (it must not silently zero-pad a mis-sized batch).

    copy=True forces a fresh device buffer even when the input is already
    a correctly-shaped jax array — required when the staged buffer will be
    *donated* but the source must survive (the pipeline re-stages a shared
    RHS every step)."""
    import jax
    import jax.numpy as jnp

    k_pad = _pad_k(eng, k)
    if getattr(b_batch, "ndim", 1) > 1 and b_batch.shape[0] != k:
        raise ValueError(f"b_batch has leading (batch) dimension "
                         f"{b_batch.shape[0]} but the factorization batch "
                         f"size is {k}")
    if isinstance(b_batch, jax.Array):
        b = b_batch
        if b.ndim == 1:
            b = jnp.broadcast_to(b, (k,) + b.shape)
        if k_pad != k:
            b = jnp.concatenate(
                [b, jnp.zeros((k_pad - k,) + b.shape[1:], b.dtype)])
        elif copy and b is b_batch:
            b = jnp.array(b)                     # fresh, donatable buffer
    else:
        b = np.asarray(b_batch, dtype=np.float64)
        if b.ndim == 1:
            b = np.broadcast_to(b, (k,) + b.shape)
        if k_pad != k:
            b = np.concatenate(
                [b, np.zeros((k_pad - k,) + b.shape[1:])])
    if eng.batch_sharding is not None:
        return jax.device_put(b, eng.batch_sharding)
    return jnp.asarray(b)


def factor_batched(an: Analysis, a_pattern, values_batch) -> BatchedFactorState:
    """K numeric factorizations (one pattern, K value sets) as a single
    pre-compiled vmapped XLA call — HYLU's repeated-solve optimization
    lifted to a batch.

    ``values_batch`` may be a host (K, nnz) array or a committed jax device
    array (no re-upload).  With ``an.opts.mesh`` set the call is sharded
    over the system-batch axis: K is padded to a multiple of the device
    count and each device factors its shard with the identical per-system
    program (bit-identical to the single-device path)."""
    import jax

    eng = jax_repeated_engine(an)
    t = {}
    t0 = time.perf_counter()
    values_dev, values_host, k = _stage_values(eng, values_batch)
    jf = eng.refactor_batched(values_dev)
    jax.block_until_ready(jf.vals)
    t["factor_batched"] = time.perf_counter() - t0
    return BatchedFactorState(
        analysis=an, a_pattern=_pattern_of(a_pattern),
        values_dev=values_dev, vals=jf.vals, inode_perm=jf.inode_perm,
        n_perturb=np.asarray(jf.n_perturb)[:k], timings=t, k=k,
        _values_host=values_host)


def solve_batched(bst: BatchedFactorState, b_batch: np.ndarray,
                  refine: bool | None = None, donate: bool = False) -> tuple:
    """Batched substitution + iterative refinement, fused on device: X[k]
    solves A_k x = b_k against the K stored factorizations as ONE
    pre-compiled XLA program — substitution, the batched CSR residual
    matvec (pattern as compile-time constants) and the whole refinement
    loop (``lax.while_loop`` with per-system improved/converged masking)
    execute without any per-iteration host transfer.  Under a mesh the
    program is shard_mapped over the system batch (padded K; results are
    sliced back and bit-identical to the single-device path).

    b_batch: (K, n), (n,) broadcast across the batch, or (K, n, m)
    multi-RHS (adjoint/sensitivity workloads); host or committed jax
    arrays.  Returns (X, info); info["residual"] is (K,) — or (K, m) for
    multi-RHS — and info["n_refine_per_system"] counts accepted refinement
    steps per system/RHS.  refine=False skips refinement; refine=None/True
    runs it until converged, stalled, or refine_max_iter.

    donate=True donates the A-values and RHS buffers into the call (the
    sequence-pipeline mode): XLA may reuse their memory, and ``bst`` is
    marked consumed — further solves against it raise."""
    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    if bst.consumed:
        raise RuntimeError(
            "this BatchedFactorState was consumed by a donating solve — "
            "refactor (factor_batched) before solving again")
    t0 = time.perf_counter()
    if donate and bst._values_host is None:
        _ = bst.values_batch    # materialize the host oracle before the
        #                         device buffer is donated away
    b_dev = _stage_rhs(eng, b_batch, bst.k)
    solver = eng.refined_batched_solver(*bst.a_pattern, donate=donate)
    max_iter = 0 if refine is False else opts.refine_max_iter
    x, resid, n_iter, n_ref_sys = solver(
        bst.vals, bst.inode_perm, bst.values_dev,
        b_dev, max_iter, opts.refine_tol)
    if donate:
        bst.consumed = True
        bst.values_dev = None
    k = bst.k
    x = np.asarray(x)[:k]
    info = dict(residual=np.asarray(resid)[:k], n_refine=int(n_iter),
                n_refine_per_system=np.asarray(n_ref_sys)[:k],
                n_perturb=bst.n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def _solve_batched_hostloop(bst: BatchedFactorState, b_batch: np.ndarray,
                            refine: bool | None = None) -> tuple:
    """Pre-fusion reference implementation of :func:`solve_batched`: device
    substitution but numpy residuals and a Python refinement loop (one
    host round-trip per iteration).  Kept as the benchmark baseline the
    fused path is measured against, and as a parity oracle — same
    per-system improved/converged masking, same multi-RHS shapes."""
    import jax.numpy as jnp

    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    b_batch = np.asarray(b_batch, dtype=np.float64)
    if b_batch.ndim == 1:
        b_batch = np.broadcast_to(b_batch, (bst.k, b_batch.shape[0]))

    # the oracle path always runs unsharded at the true batch size: slice
    # any mesh padding off the (possibly sharded) device buffers
    vals_k, inode_k = bst.vals[:bst.k], bst.inode_perm[:bst.k]

    def residuals(x):
        r = b_batch - _batched_matvec(bst.a_pattern, bst.values_batch, x)
        return r, np.abs(r).sum(axis=1) / bnorm

    bnorm = np.abs(b_batch).sum(axis=1)          # (K,) or (K, m)
    bnorm = np.where(bnorm == 0.0, 1.0, bnorm)
    x = np.asarray(eng.apply_batched(vals_k, inode_k,
                                     jnp.asarray(b_batch)))
    r, resid = residuals(x)
    n_ref = 0
    alive = np.ones(resid.shape, bool)
    max_iter = 0 if refine is False else opts.refine_max_iter
    for _ in range(max_iter):
        need = alive & (resid > opts.refine_tol)
        if not need.any():
            break
        x2 = x + np.asarray(eng.apply_batched(vals_k, inode_k,
                                              jnp.asarray(r)))
        r2, resid2 = residuals(x2)
        n_ref += 1
        improved = resid2 < resid
        upd = need & improved                     # mirror the fused masking
        x = np.where(upd[:, None], x2, x)
        r = np.where(upd[:, None], r2, r)
        resid = np.where(upd, resid2, resid)
        alive = alive & (improved | ~need)
    info = dict(residual=resid, n_refine=n_ref, n_perturb=bst.n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def _seed_values(values_batch) -> np.ndarray:
    """The (nnz,) float64 host values that seed the analysis: system 0 of
    the (possibly committed-device) batch.  Indexes down to one row
    *before* the host transfer, so a committed (K, nnz) buffer costs one
    row D2H, not K; accepts a list/tuple of value sets, a (K, nnz) batch,
    or a single (nnz,) vector."""
    v0 = values_batch
    while isinstance(v0, (list, tuple)) or getattr(v0, "ndim", 1) > 1:
        v0 = v0[0]
    return np.asarray(v0, dtype=np.float64).copy()


def _is_step_sequence(values_batch) -> bool:
    """True when values_batch is a T-step sequence — a list/tuple of 2-D
    (K, nnz) value sets or a stacked (T, K, nnz) array — rather than one
    batched step.  A list of 1-D (nnz,) value sets keeps its historical
    meaning: ONE batched step of K systems (np.atleast_2d semantics)."""
    if isinstance(values_batch, (list, tuple)):
        if not values_batch:
            return False
        first = values_batch[0]
        ndim = getattr(first, "ndim", None)
        return (np.asarray(first).ndim if ndim is None else ndim) >= 2
    ndim = getattr(values_batch, "ndim", None)
    return ndim == 3


def solve_sequence(a_pattern, values_batch, b_batch,
                   opts: HyluOptions | None = None) -> tuple:
    """Repeated-solve convenience (the paper's §3.2 scenario, batched):
    one analysis, then batched factorizations + solves as pre-compiled
    XLA programs (sharded over the mesh when ``opts.mesh`` is set).

    a_pattern     CSR (or (indptr, indices)) — the shared sparsity pattern
    values_batch  (K, nnz) value sets — ONE batched step — or a T-step
                  sequence ((T, K, nnz) array, or a list of per-step 2-D
                  (K, nnz) arrays, host or committed jax device buffers).
                  A list of 1-D (nnz,) vectors keeps its historical
                  meaning: one batched step of K systems.  The first
                  value set seeds the analysis (matching/ordering are
                  value-dependent but stable across the mild value drift
                  of Newton/transient sequences)
    b_batch       (K, n) right-hand sides, (n,) broadcast, or (K, n, m)
                  multi-RHS (adjoint/sensitivity sweeps); for a step
                  sequence, either one such RHS reused every step or a
                  list/tuple with one entry per step

    For a single step: returns (x (K, n[, m]), info) as before.

    For a T-step sequence the calls run as an **async double-buffered
    pipeline**: while the device factors + solves step t, the host stages
    step t+1's values (``jax.device_put`` overlaps the copy with compute),
    and nothing blocks until the final gather — so H2D staging hides
    behind solves.  With ``opts.donate`` each step additionally recycles
    the previous step's factor buffers (``refactor_batched_reuse``) and
    donates the consumed value/RHS buffers, so a long refactor stream
    runs allocation-flat.  Returns (x (T, K, n[, m]), info) with
    info["residual"] (T, K[, m]) and per-step refinement counts."""
    if _is_step_sequence(values_batch):
        return _solve_sequence_pipelined(a_pattern, values_batch, b_batch,
                                         opts)
    pattern = _pattern_of(a_pattern)
    n = len(pattern[0]) - 1
    a0 = CSR(n, pattern[0], pattern[1], _seed_values(values_batch))
    an = analyze(a0, opts)
    bst = factor_batched(an, pattern, values_batch)
    x, info = solve_batched(bst, b_batch)
    info["timings"] = {"preprocess": an.timings, "factor": bst.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = "jax-batched"
    info["k"] = bst.k
    return x, info


def _solve_sequence_pipelined(a_pattern, values_steps, b_steps,
                              opts: HyluOptions | None = None) -> tuple:
    """The T-step async pipeline behind :func:`solve_sequence`.

    Per step: refactor (optionally donating the previous step's factor
    buffers into the allocation) + the fused refined solve (optionally
    donating the step's A-values/RHS buffers), dispatched asynchronously;
    step t+1's values are staged to device immediately after dispatch so
    the H2D copy overlaps the device's work on step t.  Host↔device
    synchronization happens once, at the end."""
    import jax

    steps_v = (list(values_steps) if isinstance(values_steps, (list, tuple))
               else [values_steps[t] for t in range(values_steps.shape[0])])
    n_steps = len(steps_v)
    pattern = _pattern_of(a_pattern)
    n = len(pattern[0]) - 1

    # per-step RHS must come as a list/tuple (one entry per step, each any
    # single-step shape); a bare array is a single-step RHS reused every
    # step — keeps (K, n, m) multi-RHS unambiguous
    per_step_b = isinstance(b_steps, (list, tuple))
    if per_step_b and len(b_steps) != n_steps:
        raise ValueError(f"got {len(b_steps)} per-step right-hand sides "
                         f"for {n_steps} steps")

    def b_of(t):
        return b_steps[t] if per_step_b else b_steps

    a0 = CSR(n, pattern[0], pattern[1], _seed_values(steps_v[0]))
    an = analyze(a0, opts)
    opts = an.opts
    eng = jax_repeated_engine(an)
    donate = bool(opts.donate)
    solver = eng.refined_batched_solver(*pattern, donate=donate)
    max_iter = opts.refine_max_iter

    t_all = time.perf_counter()
    # stage step 0 (the analysis already synced the host, so this is cheap);
    # copy=donate: a donated staging buffer must never BE the caller's (or
    # a shared across-steps) committed array — step t+1 restages it
    v_dev, _, k = _stage_values(eng, steps_v[0])
    b_dev = _stage_rhs(eng, b_of(0), k, copy=donate)
    outs, n_pert = [], []
    prev = None
    for t in range(n_steps):
        if donate and prev is not None:
            jf = eng.refactor_batched_reuse(prev.vals, prev.inode_perm,
                                            v_dev)
        else:
            jf = eng.refactor_batched(v_dev)
        x, resid, n_iter, n_ref = solver(jf.vals, jf.inode_perm, v_dev,
                                         b_dev, max_iter, opts.refine_tol)
        # stage step t+1 while the device chews on step t — this H2D copy
        # is the one the double-buffering hides
        if t + 1 < n_steps:
            v_dev, _, k2 = _stage_values(eng, steps_v[t + 1])
            if k2 != k:
                raise ValueError(f"step {t + 1} has batch size {k2}, "
                                 f"step 0 had {k}")
            b_dev = _stage_rhs(eng, b_of(t + 1), k, copy=donate)
        outs.append((x, resid, n_iter, n_ref))
        n_pert.append(jf.n_perturb)
        prev = jf
    jax.block_until_ready(outs[-1][0])           # the single sync point
    t_all = time.perf_counter() - t_all

    x = np.stack([np.asarray(o[0])[:k] for o in outs])
    resid = np.stack([np.asarray(o[1])[:k] for o in outs])
    info = dict(residual=resid,
                n_refine=[int(o[2]) for o in outs],
                n_refine_per_system=np.stack(
                    [np.asarray(o[3])[:k] for o in outs]),
                n_perturb=np.stack([np.asarray(p)[:k] for p in n_pert]),
                solve_time=t_all,
                timings={"preprocess": an.timings, "pipeline": t_all},
                mode=an.choice.mode, ordering=an.ordering_name,
                engine="jax-batched", k=k, steps=n_steps,
                donate=donate)
    return x, info
