"""HYLU public API: analyze → factor → solve (+ refactor for repeated solve).

Pipeline (paper §2):
  preprocessing   = MC64 matching/scaling + ordering selection + symbolic
                    factorization + kernel selection + plan build
  numeric         = hybrid-kernel factorization (ref_engine / jax_engine)
  solve           = level-scheduled substitution + iterative refinement

Transformations bookkeeping:  with Dr=diag(r), Ds=diag(s) from matching,
column permutation q (matched entry → diagonal), symmetric ordering p and
the numeric in-node pivot permutation g↦inode_perm[g]:

    M = (P_p (Dr A Ds) Q_q P_pᵀ),     L U = M[inode_perm, :]

    A x = b   ⇒   w = U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ;  z[p]=w ; y[q]=z ; x = s·y
"""
from __future__ import annotations

import dataclasses
import time
import numpy as np

from .matrix import CSR
from .matching import max_weight_matching, MatchResult
from .ordering import select_ordering
from .kernel_select import select_kernel, KernelChoice
from .plan import build_plan, FactorPlan
from .symbolic import Symbolic
from . import ref_engine
from .ref_engine import Factors, SolvePlan


@dataclasses.dataclass
class HyluOptions:
    force_mode: str | None = None          # rowrow | hybrid | supernodal
    orderings: tuple = ("min_degree", "nested_dissection", "natural")
    relax: int = 8
    max_super: int = 128
    perturb_eps: float = 1e-8
    refine_max_iter: int = 3
    refine_tol: float = 1e-12
    bulk_min_width: int = 8
    engine: str = "ref"                    # ref | jax — default numeric engine
    use_pallas: bool = False               # route jax panel updates via Pallas
    factor_schedule: str = "bucketed"      # bucketed (O(levels) trace) |
                                           # unrolled (O(nodes+edges) oracle)


@dataclasses.dataclass
class Analysis:
    n: int
    opts: HyluOptions
    match: MatchResult
    q: np.ndarray              # column permutation from matching
    p: np.ndarray              # fill-reducing ordering
    ordering_name: str
    choice: KernelChoice
    sym: Symbolic
    plan: FactorPlan
    # refactor fast path: M.data = A.data[src_map] * scale_map
    src_map: np.ndarray
    scale_map: np.ndarray
    m_pattern: tuple           # (indptr, indices) of M
    timings: dict
    # jit cache keyed on this analysis' plan: (dtype name, use_pallas) →
    # jax_engine.RepeatedSolveEngine (built lazily on first jax-engine use)
    jit_cache: dict = dataclasses.field(default_factory=dict, repr=False)


@dataclasses.dataclass
class FactorState:
    analysis: Analysis
    factors: Factors | None
    solve_plan: SolvePlan | None
    a: CSR                     # the matrix these factors correspond to
    timings: dict
    engine: str = "ref"
    jax_factors: object = None  # jax_engine.JaxFactors when engine == "jax"


@dataclasses.dataclass
class BatchedFactorState:
    """K factorizations of one sparsity pattern (K value sets), held as
    stacked device arrays — the state of the batched repeated-solve path."""
    analysis: Analysis
    a_pattern: tuple           # (indptr, indices) of the original matrices
    values_batch: np.ndarray   # (K, nnz) original A values (host oracle)
    values_dev: object         # jax (K, nnz) device copy (fused residuals —
                               # uploaded once, not per solve)
    vals: object               # jax (K, total_slots) factored panel buffers
    inode_perm: object         # jax (K, n) in-node pivot permutations
    n_perturb: np.ndarray      # (K,) perturbation counts
    timings: dict

    @property
    def k(self) -> int:
        return self.values_batch.shape[0]


def analyze(a: CSR, opts: HyluOptions | None = None, reuse=None) -> Analysis:
    """Preprocessing phase (HYLU §2.1).

    reuse: a prior Analysis of the *same matrix* — matching and ordering are
    mode-independent and are reused (benchmarking different kernel modes
    re-runs only symbolic + plan)."""
    opts = opts or HyluOptions()
    t: dict[str, float] = {}
    t0 = time.perf_counter()
    match = reuse.match if reuse is not None else max_weight_matching(a)
    t["matching"] = time.perf_counter() - t0

    # permute/scale with index-tracking data so refactor is a pure gather
    t0 = time.perf_counter()
    seg = np.repeat(np.arange(a.n), np.diff(a.indptr))
    scale_entry = match.row_scale[seg] * match.col_scale[a.indices]
    tracker = CSR(a.n, a.indptr.copy(), a.indices.copy(),
                  np.arange(a.nnz, dtype=np.float64))
    q = match.col_of_row.copy()
    b2_track = tracker.permute(np.arange(a.n), q)

    pat2 = CSR(a.n, b2_track.indptr, b2_track.indices,
               np.ones(a.nnz)).sym_pattern()
    if reuse is not None:
        p, ord_name = reuse.p, reuse.ordering_name
    else:
        p, ord_name = select_ordering(pat2, candidates=opts.orderings)
    t["ordering"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m_track = b2_track.permute(p, p)
    src_map = m_track.data.astype(np.int64)
    scale_map = scale_entry[src_map]
    pat_m = pat2.permute(p, p)
    choice, sym = select_kernel(pat_m, force_mode=opts.force_mode,
                                relax=opts.relax, max_super=opts.max_super)
    t["symbolic"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m = CSR(a.n, m_track.indptr, m_track.indices, np.ones(a.nnz))
    plan = build_plan(pat_m, m, sym, mode=choice.mode,
                      bulk_min_width=opts.bulk_min_width)
    t["plan"] = time.perf_counter() - t0
    t["total"] = sum(t.values())

    return Analysis(n=a.n, opts=opts, match=match, q=q, p=p,
                    ordering_name=ord_name, choice=choice, sym=sym, plan=plan,
                    src_map=src_map, scale_map=scale_map,
                    m_pattern=(m_track.indptr, m_track.indices), timings=t)


def _m_values(an: Analysis, a: CSR) -> CSR:
    data = a.data[an.src_map] * an.scale_map
    return CSR(a.n, an.m_pattern[0], an.m_pattern[1], data)


def jax_repeated_engine(an: Analysis, dtype=None, use_pallas: bool | None = None,
                        schedule: str | None = None):
    """The pre-compiled repeated-solve engine for this analysis.

    Built lazily and cached on the analysis (keyed by dtype/pallas/factor
    schedule), so every subsequent factor/refactor/solve through
    ``engine="jax"`` — and every batched call — is one already-compiled
    XLA program."""
    import jax.numpy as jnp

    from .jax_engine import RepeatedSolveEngine
    from .structure import build_solve_structure

    dtype = jnp.float64 if dtype is None else dtype
    use_pallas = an.opts.use_pallas if use_pallas is None else use_pallas
    schedule = an.opts.factor_schedule if schedule is None else schedule
    key = (np.dtype(dtype).name, bool(use_pallas), schedule)
    eng = an.jit_cache.get(key)
    if eng is None:
        ss = build_solve_structure(an.plan,
                                   bulk_min_width=an.opts.bulk_min_width)
        eng = RepeatedSolveEngine(
            an.plan, ss, src_map=an.src_map, scale_map=an.scale_map,
            p=an.p, q=an.q, row_scale=an.match.row_scale,
            col_scale=an.match.col_scale, perturb_eps=an.opts.perturb_eps,
            dtype=dtype, use_pallas=use_pallas, schedule=schedule,
            bulk_min_width=an.opts.bulk_min_width)
        an.jit_cache[key] = eng
    return eng


def _factor_jax(an: Analysis, a: CSR) -> FactorState:
    import jax
    import jax.numpy as jnp

    eng = jax_repeated_engine(an)
    t = {}
    t0 = time.perf_counter()
    jf = eng.refactor(jnp.asarray(a.data))
    jax.block_until_ready(jf.vals)
    t["factor"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=None, solve_plan=None, a=a,
                       timings=t, engine="jax", jax_factors=jf)


def factor(an: Analysis, a: CSR, engine=None) -> FactorState:
    """Numeric factorization + solve-plan build.

    engine: "ref" (numpy), "jax" (pre-compiled XLA; solve structure is
    static so no per-factor solve-plan rebuild), a ref-compatible engine
    module, or None → an.opts.engine."""
    engine = an.opts.engine if engine is None else engine
    if engine == "jax":
        return _factor_jax(an, a)
    if engine == "ref":
        mod = ref_engine
    elif hasattr(engine, "factor"):
        mod = engine
    else:
        raise ValueError(f"unknown engine {engine!r}: expected 'ref', 'jax', "
                         "or an engine module with a factor() function")
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a)
    f = mod.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a, timings=t)


def refactor(st: FactorState, a_new: CSR) -> FactorState:
    """Repeated-solve path: same pattern, new values; reuses the analysis
    AND the solve plan's structure (values refresh only).  On the jax
    engine this is a single pre-compiled ``a_data -> factors`` call."""
    an = st.analysis
    if st.engine == "jax":
        return _factor_jax(an, a_new)
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a_new)
    f = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a_new, timings=t)


def solve(st: FactorState, b: np.ndarray, refine: bool | None = None) -> tuple:
    """Forward/backward substitution + iterative refinement (auto when pivot
    perturbation occurred, per paper §2.3). Returns (x, info)."""
    an = st.analysis
    opts = an.opts
    t0 = time.perf_counter()

    if st.engine == "jax":
        import jax.numpy as jnp

        eng = jax_repeated_engine(an)
        jf = st.jax_factors
        n_perturb = int(jf.n_perturb)

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            return np.asarray(eng.apply(jf.vals, jf.inode_perm,
                                        jnp.asarray(rhs)))
    else:
        f = st.factors
        n_perturb = f.n_perturb

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            c = (an.match.row_scale * rhs)[an.p][f.inode_perm]
            w = ref_engine.solve_lu(st.solve_plan, c)
            z = np.empty_like(w); z[an.p] = w
            y = np.empty_like(z); y[an.q] = z
            return an.match.col_scale * y

    x = lu_apply(b)
    n_ref = 0
    bnorm = float(np.abs(b).sum()) or 1.0
    resid = float(np.abs(b - st.a.matvec(x)).sum()) / bnorm
    # auto-refine when pivot perturbation occurred (paper §2.3) or the
    # residual is above the target
    do_refine = refine if refine is not None else (
        n_perturb > 0 or resid > opts.refine_tol)
    if do_refine:
        for _ in range(opts.refine_max_iter):
            if resid <= opts.refine_tol:
                break
            r = b - st.a.matvec(x)
            x2 = x + lu_apply(r)
            resid2 = float(np.abs(b - st.a.matvec(x2)).sum()) / bnorm
            n_ref += 1
            if resid2 >= resid:
                break
            x, resid = x2, resid2
    info = dict(residual=resid, n_refine=n_ref, n_perturb=n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def solve_system(a: CSR, b: np.ndarray, opts: HyluOptions | None = None):
    """One-call convenience: analyze + factor + solve."""
    an = analyze(a, opts)
    st = factor(an, a)
    x, info = solve(st, b)
    info["timings"] = {"preprocess": an.timings, "factor": st.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = st.engine
    return x, info


# --------------------------------------------------------------------------
# batched repeated solve: K value sets of one pattern as one XLA program
# --------------------------------------------------------------------------
def _pattern_of(a_pattern) -> tuple:
    if isinstance(a_pattern, CSR):
        return (a_pattern.indptr, a_pattern.indices)
    indptr, indices = a_pattern
    return (np.asarray(indptr), np.asarray(indices))


def _batched_matvec(pattern: tuple, values_batch: np.ndarray,
                    x_batch: np.ndarray) -> np.ndarray:
    """(A_k x_k) for K CSR matrices sharing one pattern: one gather +
    row-segment reduction for the whole batch.

    Host-side (numpy) reference: the production jax path computes residuals
    with the device matvec baked into the fused solver
    (``jax_engine.make_csr_matvec_batched``); this stays as the oracle for
    tests and as the host-loop benchmark baseline.  x_batch is (K, n) or
    (K, n, m) multi-RHS."""
    indptr, indices = pattern
    if x_batch.ndim == 3:
        prod = values_batch[:, :, None] * x_batch[:, indices]
    else:
        prod = values_batch * x_batch[:, indices]
    counts = np.diff(indptr)
    if len(counts) == 0:
        return np.zeros_like(x_batch)
    if counts.min() > 0:
        return np.add.reduceat(prod, indptr[:-1], axis=1)
    # reduceat mishandles empty rows; fall back to per-batch scatter-add
    # (preserves the batch dtype, unlike bincount which promotes to float64)
    seg = np.repeat(np.arange(len(counts)), counts)
    out = np.zeros((x_batch.shape[0], len(counts)) + x_batch.shape[2:],
                   dtype=prod.dtype)
    for k in range(out.shape[0]):
        np.add.at(out[k], seg, prod[k])
    return out


def factor_batched(an: Analysis, a_pattern, values_batch) -> BatchedFactorState:
    """K numeric factorizations (one pattern, K value sets) as a single
    pre-compiled vmapped XLA call — HYLU's repeated-solve optimization
    lifted to a batch."""
    import jax
    import jax.numpy as jnp

    eng = jax_repeated_engine(an)
    values_batch = np.ascontiguousarray(
        np.atleast_2d(np.asarray(values_batch, dtype=np.float64)))
    t = {}
    t0 = time.perf_counter()
    values_dev = jnp.asarray(values_batch)
    jf = eng.refactor_batched(values_dev)
    jax.block_until_ready(jf.vals)
    t["factor_batched"] = time.perf_counter() - t0
    return BatchedFactorState(
        analysis=an, a_pattern=_pattern_of(a_pattern),
        values_batch=values_batch, values_dev=values_dev,
        vals=jf.vals, inode_perm=jf.inode_perm,
        n_perturb=np.asarray(jf.n_perturb), timings=t)


def solve_batched(bst: BatchedFactorState, b_batch: np.ndarray,
                  refine: bool | None = None) -> tuple:
    """Batched substitution + iterative refinement, fused on device: X[k]
    solves A_k x = b_k against the K stored factorizations as ONE
    pre-compiled XLA program — substitution, the batched CSR residual
    matvec (pattern as compile-time constants) and the whole refinement
    loop (``lax.while_loop`` with per-system improved/converged masking)
    execute without any per-iteration host transfer.

    b_batch: (K, n), (n,) broadcast across the batch, or (K, n, m)
    multi-RHS (adjoint/sensitivity workloads).  Returns (X, info);
    info["residual"] is (K,) — or (K, m) for multi-RHS — and
    info["n_refine_per_system"] counts accepted refinement steps per
    system/RHS.  refine=False skips refinement; refine=None/True runs it
    until converged, stalled, or refine_max_iter."""
    import jax.numpy as jnp

    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    b_batch = np.asarray(b_batch, dtype=np.float64)
    if b_batch.ndim == 1:
        b_batch = np.broadcast_to(b_batch, (bst.k, b_batch.shape[0]))
    solver = eng.refined_batched_solver(*bst.a_pattern)
    max_iter = 0 if refine is False else opts.refine_max_iter
    x, resid, n_iter, n_ref_sys = solver(
        bst.vals, bst.inode_perm, bst.values_dev,
        jnp.asarray(b_batch), max_iter, opts.refine_tol)
    x = np.asarray(x)
    info = dict(residual=np.asarray(resid), n_refine=int(n_iter),
                n_refine_per_system=np.asarray(n_ref_sys),
                n_perturb=bst.n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def _solve_batched_hostloop(bst: BatchedFactorState, b_batch: np.ndarray,
                            refine: bool | None = None) -> tuple:
    """Pre-fusion reference implementation of :func:`solve_batched`: device
    substitution but numpy residuals and a Python refinement loop (one
    host round-trip per iteration).  Kept as the benchmark baseline the
    fused path is measured against, and as a parity oracle — same
    per-system improved/converged masking, same multi-RHS shapes."""
    import jax.numpy as jnp

    an = bst.analysis
    opts = an.opts
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    b_batch = np.asarray(b_batch, dtype=np.float64)
    if b_batch.ndim == 1:
        b_batch = np.broadcast_to(b_batch, (bst.k, b_batch.shape[0]))

    def residuals(x):
        r = b_batch - _batched_matvec(bst.a_pattern, bst.values_batch, x)
        return r, np.abs(r).sum(axis=1) / bnorm

    bnorm = np.abs(b_batch).sum(axis=1)          # (K,) or (K, m)
    bnorm = np.where(bnorm == 0.0, 1.0, bnorm)
    x = np.asarray(eng.apply_batched(bst.vals, bst.inode_perm,
                                     jnp.asarray(b_batch)))
    r, resid = residuals(x)
    n_ref = 0
    alive = np.ones(resid.shape, bool)
    max_iter = 0 if refine is False else opts.refine_max_iter
    for _ in range(max_iter):
        need = alive & (resid > opts.refine_tol)
        if not need.any():
            break
        x2 = x + np.asarray(eng.apply_batched(bst.vals, bst.inode_perm,
                                              jnp.asarray(r)))
        r2, resid2 = residuals(x2)
        n_ref += 1
        improved = resid2 < resid
        upd = need & improved                     # mirror the fused masking
        x = np.where(upd[:, None], x2, x)
        r = np.where(upd[:, None], r2, r)
        resid = np.where(upd, resid2, resid)
        alive = alive & (improved | ~need)
    info = dict(residual=resid, n_refine=n_ref, n_perturb=bst.n_perturb,
                solve_time=time.perf_counter() - t0)
    return x, info


def solve_sequence(a_pattern, values_batch, b_batch,
                   opts: HyluOptions | None = None) -> tuple:
    """Repeated-solve convenience (the paper's §3.2 scenario, batched):
    one analysis, then K factorizations + K solves as pre-compiled batched
    XLA programs.

    a_pattern     CSR (or (indptr, indices)) — the shared sparsity pattern
    values_batch  (K, nnz) value sets; values_batch[0] seeds the analysis
                  (matching/ordering are value-dependent but stable across
                  the mild value drift of Newton/transient sequences)
    b_batch       (K, n) right-hand sides, (n,) broadcast, or (K, n, m)
                  multi-RHS (adjoint/sensitivity sweeps)
    """
    values_batch = np.atleast_2d(np.asarray(values_batch, dtype=np.float64))
    pattern = _pattern_of(a_pattern)
    n = len(pattern[0]) - 1
    a0 = CSR(n, pattern[0], pattern[1], values_batch[0].copy())
    an = analyze(a0, opts)
    bst = factor_batched(an, pattern, values_batch)
    x, info = solve_batched(bst, b_batch)
    info["timings"] = {"preprocess": an.timings, "factor": bst.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = "jax-batched"
    info["k"] = bst.k
    return x, info
