"""HYLU public API facade: analyze → factor → solve (+ repeated/batched).

Pipeline (paper §2):
  preprocessing   = MC64 matching/scaling + ordering selection + symbolic
                    factorization + kernel selection + plan build
  numeric         = hybrid-kernel factorization (ref_engine / jax_engine)
  solve           = level-scheduled substitution + iterative refinement

This module is a thin re-exporting facade over the layered core stack —
every name that ever lived in the old ``api.py`` monolith keeps importing
from here:

  :mod:`repro.core.options`    HyluOptions, mesh resolution, and the
                               pattern/plan fingerprints (the content
                               address of the plan cache)
  :mod:`repro.core.analysis`   Analysis/FactorState + the scalar
                               analyze/factor/refactor/solve lifecycle and
                               the per-analysis compiled-engine cache
  :mod:`repro.core.batched`    BatchedFactorState + the batched/sharded
                               repeated-solve path (factor_batched /
                               solve_batched / solve_sequence pipelines)

On top of these sit :mod:`repro.core.plan_cache` (content-addressed LRU
cache + disk persistence of analyses under ``checkpoints/``) and
:mod:`repro.serve.solver_service` (mixed-pattern serving: group-by-
fingerprint dispatch onto the batched engines).  Full contracts:
docs/API.md; architecture: docs/ARCHITECTURE.md.
"""
from __future__ import annotations

from .options import (HyluOptions, PLAN_OPTION_FIELDS, plan_options_key,
                      pattern_key, plan_fingerprint, dtype_name, np_dtype,
                      resolve_perturb_eps, resolve_refine_tol,
                      resolve_dtype_names, _resolve_mesh, _mesh_cache_key)
from .analysis import (Analysis, FactorState, analyze, factor, refactor,
                       solve, solve_system, jax_repeated_engine,
                       _m_values, _factor_jax)
from .batched import (BatchedFactorState, factor_batched, solve_batched,
                      solve_sequence, _pattern_of, _batched_matvec,
                      _pad_k, _stage_values, _stage_rhs,
                      _solve_batched_hostloop, _seed_values,
                      _is_step_sequence, _solve_sequence_pipelined)

__all__ = [
    "HyluOptions", "PLAN_OPTION_FIELDS", "plan_options_key",
    "pattern_key", "plan_fingerprint",
    "dtype_name", "np_dtype", "resolve_perturb_eps", "resolve_refine_tol",
    "resolve_dtype_names",
    "Analysis", "FactorState", "BatchedFactorState",
    "analyze", "factor", "refactor", "solve", "solve_system",
    "jax_repeated_engine",
    "factor_batched", "solve_batched", "solve_sequence",
    # private oracles/helpers kept importable for tests and benchmarks
    "_resolve_mesh", "_mesh_cache_key", "_m_values", "_factor_jax",
    "_pattern_of", "_batched_matvec", "_pad_k", "_stage_values",
    "_stage_rhs", "_solve_batched_hostloop", "_seed_values",
    "_is_step_sequence", "_solve_sequence_pipelined",
]
