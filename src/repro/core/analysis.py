"""Analyze / factor / refactor / solve — the scalar HYLU lifecycle.

Middle layer of the core stack (options → analysis → batched → api facade):
owns the ``Analysis`` artifact (the reusable, content-addressed product of
the preprocessing phase), the per-analysis compiled-engine cache, and the
scalar numeric lifecycle.  The batched/sharded paths live one layer up in
:mod:`repro.core.batched`; callers import everything through the
:mod:`repro.core.api` facade.

Transformations bookkeeping:  with Dr=diag(r), Ds=diag(s) from matching,
column permutation q (matched entry → diagonal), symmetric ordering p and
the numeric in-node pivot permutation g↦inode_perm[g]:

    M = (P_p (Dr A Ds) Q_q P_pᵀ),     L U = M[inode_perm, :]

    A x = b   ⇒   w = U⁻¹ L⁻¹ ((r·b)[p][inode_perm]) ;  z[p]=w ; y[q]=z ; x = s·y
"""
from __future__ import annotations

import dataclasses
import time
import numpy as np

from .matrix import CSR
from .matching import max_weight_matching, MatchResult
from .ordering import select_ordering
from .kernel_select import select_kernel, KernelChoice
from .plan import build_plan, FactorPlan
from .symbolic import Symbolic
from . import ref_engine
from .ref_engine import Factors, SolvePlan
from .options import (HyluOptions, pattern_key, plan_fingerprint,
                      _resolve_mesh, _mesh_cache_key, np_dtype,
                      resolve_perturb_eps, resolve_refine_tol)


@dataclasses.dataclass
class Analysis:
    """The reusable product of :func:`analyze` (HYLU §2.1): matching,
    ordering, symbolic structure, the static FactorPlan, and the refactor
    gather maps — everything value-independent about one sparsity pattern.
    Also carries the per-pattern cache of compiled jax engines, so keep it
    alive across refactor/solve streams (the plan cache does exactly that).

    ``pattern_key``/``fingerprint`` are the content address: the pattern
    hash alone, and pattern + plan-affecting options (see
    :mod:`repro.core.options`).  They gate ``analyze(reuse=...)`` and key
    the plan cache."""
    n: int
    opts: HyluOptions
    match: MatchResult
    q: np.ndarray              # column permutation from matching
    p: np.ndarray              # fill-reducing ordering
    ordering_name: str
    choice: KernelChoice
    sym: Symbolic
    plan: FactorPlan
    # refactor fast path: M.data = A.data[src_map] * scale_map
    src_map: np.ndarray
    scale_map: np.ndarray
    m_pattern: tuple           # (indptr, indices) of M
    timings: dict
    pattern_key: str = ""      # sha256 of (n, indptr, indices) alone
    fingerprint: str = ""      # pattern_key + plan-affecting options
    # jit cache keyed on this analysis' plan: (factor dtype, refine dtype,
    # use_pallas, schedule, mesh) → jax_engine.RepeatedSolveEngine (built
    # lazily on first jax-engine use)
    jit_cache: dict = dataclasses.field(default_factory=dict, repr=False)


@dataclasses.dataclass
class FactorState:
    """One numeric factorization of one value set — what :func:`solve`
    consumes and :func:`refactor` refreshes (ref engine: numpy factors +
    solve plan; jax engine: device JaxFactors)."""
    analysis: Analysis
    factors: Factors | None
    solve_plan: SolvePlan | None
    a: CSR                     # the matrix these factors correspond to
    timings: dict
    engine: str = "ref"
    jax_factors: object = None  # jax_engine.JaxFactors when engine == "jax"


def analyze(a: CSR, opts: HyluOptions | None = None, reuse=None) -> Analysis:
    """Preprocessing phase (HYLU §2.1).

    reuse: a prior Analysis of the *same sparsity pattern* — matching and
    ordering are mode-independent and are reused (benchmarking different
    kernel modes re-runs only symbolic + plan).  The reused analysis is
    validated against the new matrix's pattern fingerprint; a mismatch
    raises ``ValueError`` instead of producing silently wrong factors."""
    opts = opts or HyluOptions()
    pkey = pattern_key(a)
    if reuse is not None:
        reuse_key = getattr(reuse, "pattern_key", "")
        if reuse_key != pkey:
            raise ValueError(
                "analyze(reuse=...): the reused analysis was built for a "
                "different sparsity pattern "
                f"(pattern_key {reuse_key[:12] or '<unset>'}… vs "
                f"{pkey[:12]}… for this matrix, n={reuse.n} vs {a.n}); "
                "reusing it would produce silently wrong factors — "
                "run a fresh analyze() for this pattern")
    t: dict[str, float] = {}
    t0 = time.perf_counter()
    match = reuse.match if reuse is not None else max_weight_matching(a)
    t["matching"] = time.perf_counter() - t0

    # permute/scale with index-tracking data so refactor is a pure gather
    t0 = time.perf_counter()
    seg = np.repeat(np.arange(a.n), np.diff(a.indptr))
    scale_entry = match.row_scale[seg] * match.col_scale[a.indices]
    tracker = CSR(a.n, a.indptr.copy(), a.indices.copy(),
                  np.arange(a.nnz, dtype=np.float64))
    q = match.col_of_row.copy()
    b2_track = tracker.permute(np.arange(a.n), q)

    pat2 = CSR(a.n, b2_track.indptr, b2_track.indices,
               np.ones(a.nnz)).sym_pattern()
    if reuse is not None:
        p, ord_name = reuse.p, reuse.ordering_name
    else:
        p, ord_name = select_ordering(pat2, candidates=opts.orderings)
    t["ordering"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m_track = b2_track.permute(p, p)
    src_map = m_track.data.astype(np.int64)
    scale_map = scale_entry[src_map]
    pat_m = pat2.permute(p, p)
    choice, sym = select_kernel(pat_m, force_mode=opts.force_mode,
                                relax=opts.relax, max_super=opts.max_super)
    t["symbolic"] = time.perf_counter() - t0

    if opts.amalg_fill_tol > 0:
        from .structure import amalgamate_supernodes
        t0 = time.perf_counter()
        sym, amalg_stats = amalgamate_supernodes(
            sym, fill_tol=opts.amalg_fill_tol, max_super=opts.max_super)
        choice.stats["amalg"] = amalg_stats
        t["amalgamate"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    m = CSR(a.n, m_track.indptr, m_track.indices, np.ones(a.nnz))
    plan = build_plan(pat_m, m, sym, mode=choice.mode,
                      bulk_min_width=opts.bulk_min_width)
    t["plan"] = time.perf_counter() - t0
    t["total"] = sum(t.values())

    return Analysis(n=a.n, opts=opts, match=match, q=q, p=p,
                    ordering_name=ord_name, choice=choice, sym=sym, plan=plan,
                    src_map=src_map, scale_map=scale_map,
                    m_pattern=(m_track.indptr, m_track.indices), timings=t,
                    pattern_key=pkey,
                    fingerprint=plan_fingerprint(a, opts, pkey=pkey))


def _m_values(an: Analysis, a: CSR) -> CSR:
    data = a.data[an.src_map] * an.scale_map
    return CSR(a.n, an.m_pattern[0], an.m_pattern[1], data)


def jax_repeated_engine(an: Analysis, dtype=None, use_pallas: bool | None = None,
                        schedule: str | None = None, mesh=None,
                        refine_dtype=None):
    """The pre-compiled repeated-solve engine for this analysis.

    Built lazily and cached on the analysis (keyed by factor/refine dtype,
    pallas, factor schedule and mesh devices), so every subsequent
    factor/refactor/solve through ``engine="jax"`` — and every batched call
    — is one already-compiled XLA program.  ``dtype`` (default
    ``an.opts.factor_dtype``) is the factor-panel/substitution precision;
    ``refine_dtype`` (default ``an.opts.refine_dtype``, ``"auto"`` → fp64
    whenever x64 is on) is the residual/accumulation precision.  ``mesh``
    (default ``an.opts.mesh``) shards the *batched* programs over the
    system-batch axis; the scalar refactor/apply programs are always
    single-device."""
    import jax

    from .jax_engine import RepeatedSolveEngine
    from .structure import build_solve_structure

    dtype = np_dtype(an.opts.factor_dtype) if dtype is None else dtype
    if refine_dtype is None and an.opts.refine_dtype not in (None, "auto"):
        refine_dtype = np_dtype(an.opts.refine_dtype)
    # the engine applies the same "auto" rule when refine_dtype is None;
    # resolve here too so the cache key names the engine actually built
    rname = (np.dtype(refine_dtype).name if refine_dtype is not None
             else ("float64" if jax.config.jax_enable_x64
                   else np.dtype(dtype).name))
    use_pallas = an.opts.use_pallas if use_pallas is None else use_pallas
    schedule = an.opts.factor_schedule if schedule is None else schedule
    mesh = _resolve_mesh(an.opts.mesh if mesh is None else mesh)
    key = (np.dtype(dtype).name, rname, bool(use_pallas), schedule,
           _mesh_cache_key(mesh))
    eng = an.jit_cache.get(key)
    if eng is None:
        ss = build_solve_structure(an.plan,
                                   bulk_min_width=an.opts.bulk_min_width)
        eng = RepeatedSolveEngine(
            an.plan, ss, src_map=an.src_map, scale_map=an.scale_map,
            p=an.p, q=an.q, row_scale=an.match.row_scale,
            col_scale=an.match.col_scale,
            perturb_eps=resolve_perturb_eps(an.opts, dtype),
            dtype=dtype, refine_dtype=refine_dtype, use_pallas=use_pallas,
            schedule=schedule, bulk_min_width=an.opts.bulk_min_width,
            mesh=mesh)
        an.jit_cache[key] = eng
    return eng


def _factor_jax(an: Analysis, a: CSR) -> FactorState:
    import jax
    import jax.numpy as jnp

    eng = jax_repeated_engine(an)
    t = {}
    t0 = time.perf_counter()
    jf = eng.refactor(jnp.asarray(a.data))
    jax.block_until_ready(jf.vals)
    t["factor"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=None, solve_plan=None, a=a,
                       timings=t, engine="jax", jax_factors=jf)


def factor(an: Analysis, a: CSR, engine=None) -> FactorState:
    """Numeric factorization + solve-plan build.

    engine: "ref" (numpy), "jax" (pre-compiled XLA; solve structure is
    static so no per-factor solve-plan rebuild), a ref-compatible engine
    module, or None → an.opts.engine."""
    engine = an.opts.engine if engine is None else engine
    if engine == "jax":
        return _factor_jax(an, a)
    if engine == "ref":
        mod = ref_engine
    elif hasattr(engine, "factor"):
        mod = engine
    else:
        raise ValueError(f"unknown engine {engine!r}: expected 'ref', 'jax', "
                         "or an engine module with a factor() function")
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a)
    f = mod.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a, timings=t)


def refactor(st: FactorState, a_new: CSR) -> FactorState:
    """Repeated-solve path: same pattern, new values; reuses the analysis
    AND the solve plan's structure (values refresh only).  On the jax
    engine this is a single pre-compiled ``a_data -> factors`` call."""
    an = st.analysis
    if st.engine == "jax":
        return _factor_jax(an, a_new)
    t = {}
    t0 = time.perf_counter()
    m = _m_values(an, a_new)
    f = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    t["factor"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    sp = ref_engine.build_solve_plan(f, bulk_min_width=an.opts.bulk_min_width)
    t["solve_plan"] = time.perf_counter() - t0
    return FactorState(analysis=an, factors=f, solve_plan=sp, a=a_new, timings=t)


def solve(st: FactorState, b: np.ndarray, refine: bool | None = None) -> tuple:
    """Forward/backward substitution + iterative refinement (auto when pivot
    perturbation occurred, per paper §2.3). Returns (x, info)."""
    an = st.analysis
    opts = an.opts
    t0 = time.perf_counter()

    if st.engine == "jax":
        import jax.numpy as jnp

        eng = jax_repeated_engine(an)
        jf = st.jax_factors
        n_perturb = int(jf.n_perturb)
        rtol = resolve_refine_tol(opts, eng.refine_dtype)

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            return np.asarray(eng.apply(jf.vals, jf.inode_perm,
                                        jnp.asarray(rhs)))
    else:
        f = st.factors
        n_perturb = f.n_perturb
        rtol = resolve_refine_tol(opts, "float64")

        def lu_apply(rhs: np.ndarray) -> np.ndarray:
            c = (an.match.row_scale * rhs)[an.p][f.inode_perm]
            w = ref_engine.solve_lu(st.solve_plan, c)
            z = np.empty_like(w); z[an.p] = w
            y = np.empty_like(z); y[an.q] = z
            return an.match.col_scale * y

    # accumulate x and the residual in float64 on the host regardless of the
    # engine's factor dtype (the batched path does the same in refine_dtype)
    x = np.asarray(lu_apply(b), dtype=np.float64)
    n_ref = 0
    bnorm = float(np.abs(b).sum()) or 1.0
    resid = float(np.abs(b - st.a.matvec(x)).sum()) / bnorm
    # auto-refine when pivot perturbation occurred (paper §2.3) or the
    # residual is above the target
    do_refine = refine if refine is not None else (
        n_perturb > 0 or resid > rtol)
    if do_refine:
        for _ in range(opts.refine_max_iter):
            if resid <= rtol:
                break
            r = b - st.a.matvec(x)
            x2 = x + lu_apply(r)
            resid2 = float(np.abs(b - st.a.matvec(x2)).sum()) / bnorm
            n_ref += 1
            if resid2 >= resid:
                break
            x, resid = x2, resid2
    info = dict(residual=resid, n_refine=n_ref, n_perturb=n_perturb,
                refine_failed=bool(do_refine and resid > rtol),
                solve_time=time.perf_counter() - t0)
    return x, info


def solve_system(a: CSR, b: np.ndarray, opts: HyluOptions | None = None):
    """One-call convenience: analyze + factor + solve."""
    an = analyze(a, opts)
    st = factor(an, a)
    x, info = solve(st, b)
    info["timings"] = {"preprocess": an.timings, "factor": st.timings}
    info["mode"] = an.choice.mode
    info["ordering"] = an.ordering_name
    info["engine"] = st.engine
    return x, info
