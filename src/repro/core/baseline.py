"""Internal baselines (the paper's comparison structure, §4).

The paper's thesis: *integrating different numerical kernels and elaborately
selecting them based on the matrix sparsity pattern* beats any single-kernel
solver across sparsity regimes.  We materialize that comparison with three
fully-functional solver configurations sharing the same engine:

  pardiso_like  — supernodal-only (aggressive amalgamation; level-3 BLAS
                  everywhere) — the MKL PARDISO / SuperLU design point.
  klu_like      — row-row only (no supernodes) — the KLU/NICSLU design point.
  hylu          — hybrid kernels + smart selection (the paper).

``scipy.sparse.linalg.splu`` (SuperLU, the paper's ref [2]) is used as the
external baseline in benchmarks.
"""
from __future__ import annotations

from .api import HyluOptions


def hylu_options(**kw) -> HyluOptions:
    return HyluOptions(force_mode=None, **kw)


def pardiso_like_options(**kw) -> HyluOptions:
    kw.setdefault("relax", 32)
    kw.setdefault("max_super", 256)
    return HyluOptions(force_mode="supernodal", **kw)


def klu_like_options(**kw) -> HyluOptions:
    return HyluOptions(force_mode="rowrow", **kw)


BASELINES = {
    "hylu": hylu_options,
    "pardiso_like": pardiso_like_options,
    "klu_like": klu_like_options,
}
