"""Static L/U structure + slot maps derived from a FactorPlan.

The factored values live in the flat panel buffer ``vals``; every L/U entry
has a *static* slot there (in-node pivoting permutes which original row a
panel row holds, never the slot layout).  These maps let the JAX solve,
transpose-solve (adjoint) and refactorization paths gather L/U values with
compile-time-constant indices.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .plan import FactorPlan


@dataclasses.dataclass
class LUStructure:
    n: int
    # L strictly-lower (unit diag implicit), CSR by rows
    l_indptr: np.ndarray
    l_indices: np.ndarray
    l_slots: np.ndarray
    # U strictly-upper, CSR by rows, diag separate
    u_indptr: np.ndarray
    u_indices: np.ndarray
    u_slots: np.ndarray
    u_diag_slots: np.ndarray


def lu_structure(plan: FactorPlan) -> LUStructure:
    n = plan.n
    lr_pt = [0]; lr_ix = []; lr_sl = []
    ur_pt = [0]; ur_ix = []; ur_sl = []
    ud_sl = np.empty(n, dtype=np.int64)
    for nd in plan.nodes:
        off = int(plan.panel_offset[nd.nid])
        nr, w, ls = nd.nr, nd.width, nd.lsize
        pat = nd.pattern
        for q in range(nr):
            g = nd.r0 + q
            base = off + q * w
            # L: prefix cols + in-block strictly-lower
            lr_ix.extend(pat[:ls].tolist())
            lr_sl.extend(range(base, base + ls))
            lr_ix.extend(range(nd.r0, nd.r0 + q))
            lr_sl.extend(range(base + ls, base + ls + q))
            lr_pt.append(len(lr_ix))
            # U: strictly-upper in-block + suffix; diag separate
            ud_sl[g] = base + ls + q
            ur_ix.extend(range(g + 1, nd.r0 + nr))
            ur_sl.extend(range(base + ls + q + 1, base + ls + nr))
            ur_ix.extend(pat[ls + nr:].tolist())
            ur_sl.extend(range(base + ls + nr, base + w))
            ur_pt.append(len(ur_ix))
    return LUStructure(
        n=n,
        l_indptr=np.array(lr_pt, dtype=np.int64),
        l_indices=np.array(lr_ix, dtype=np.int64),
        l_slots=np.array(lr_sl, dtype=np.int64),
        u_indptr=np.array(ur_pt, dtype=np.int64),
        u_indices=np.array(ur_ix, dtype=np.int64),
        u_slots=np.array(ur_sl, dtype=np.int64),
        u_diag_slots=ud_sl,
    )


def transpose_csr(n, indptr, indices, slots):
    """CSC view == CSR of the transpose, keeping slot association."""
    rows = np.repeat(np.arange(n), np.diff(indptr))
    order = np.lexsort((rows, indices))
    t_rows = indices[order]
    t_cols = rows[order]
    t_slots = slots[order]
    t_indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(t_indptr, t_rows + 1, 1)
    return np.cumsum(t_indptr), t_cols, t_slots


@dataclasses.dataclass
class TriSched:
    """Level schedule for one triangular solve, flattened per level.
    Per level k: rows[k] (unknowns finalized), cols[k]/slot[k]/seg[k]
    (dependencies; slot indexes the flat panel buffer)."""
    rows: list
    cols: list
    slot: list
    seg: list
    n_bulk: int
    n_levels: int


def tri_schedule(n, indptr, indices, slots, lower: bool,
                 bulk_min_width: int = 8) -> TriSched:
    """Levelize a triangular system given as strictly-tri CSR. ``lower``
    selects dependency direction (forward vs backward substitution)."""
    lev = np.zeros(n, dtype=np.int64)
    rng = range(n) if lower else range(n - 1, -1, -1)
    for i in rng:
        s, e = indptr[i], indptr[i + 1]
        if e > s:
            lev[i] = 1 + lev[indices[s:e]].max()
    nl = int(lev.max()) + 1 if n else 0
    rows_l, cols_l, slot_l, seg_l = [], [], [], []
    n_bulk = 0
    for k in range(nl):
        rows = np.where(lev == k)[0]
        cnt = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
        seg = np.repeat(np.arange(len(rows)), cnt)
        take = (np.concatenate([np.arange(indptr[i], indptr[i + 1]) for i in rows])
                if cnt.sum() else np.empty(0, np.int64))
        rows_l.append(rows); cols_l.append(indices[take])
        slot_l.append(slots[take]); seg_l.append(seg)
        if len(rows) >= bulk_min_width:
            n_bulk += 1
    return TriSched(rows_l, cols_l, slot_l, seg_l, n_bulk, nl)


@dataclasses.dataclass
class BlockNode:
    """Static per-node gather maps for the block (panel) substitution path.

    The dense diagonal block (``blk_slots``) and the off-block L-prefix /
    U-suffix rectangles are read straight out of the flat panel buffer with
    these compile-time index matrices, so a solve can run node-by-node as
    dense GEMV/TRSM ops — the shape the Pallas TRSM kernel wants — instead
    of row-by-row levels."""
    r0: int
    nr: int
    pre_cols: np.ndarray    # (lsize,)  global cols of the L prefix
    pre_slots: np.ndarray   # (nr, lsize) flat slots of the L prefix
    suf_cols: np.ndarray    # (usize,)  global cols of the U suffix
    suf_slots: np.ndarray   # (nr, usize) flat slots of the U suffix
    blk_slots: np.ndarray   # (nr, nr) flat slots of the dense diagonal block
                            # (strict lower = L values, upper incl. diag = U)


def block_schedule(plan: FactorPlan) -> list:
    """Per-node block maps, ascending r0 (forward L order; reverse for U)."""
    nodes = []
    for nd in plan.nodes:
        off = int(plan.panel_offset[nd.nid])
        nr, w, ls = nd.nr, nd.width, nd.lsize
        row = off + np.arange(nr, dtype=np.int64)[:, None] * w
        nodes.append(BlockNode(
            r0=nd.r0, nr=nr,
            pre_cols=nd.pattern[:ls].astype(np.int64),
            pre_slots=row + np.arange(ls, dtype=np.int64)[None, :],
            suf_cols=nd.pattern[ls + nr:].astype(np.int64),
            suf_slots=row + ls + nr + np.arange(nd.usize, dtype=np.int64)[None, :],
            blk_slots=row + ls + np.arange(nr, dtype=np.int64)[None, :],
        ))
    return nodes


@dataclasses.dataclass
class SolveStructure:
    """Everything the JAX solve/adjoint needs, all static."""
    n: int
    lu: LUStructure
    l_fwd: TriSched       # L y = c      (forward)
    u_bwd: TriSched       # U w = y      (backward)
    lt_bwd: TriSched      # Lᵀ w = y     (backward; adjoint path)
    ut_fwd: TriSched      # Uᵀ y = c     (forward;  adjoint path)
    blocks: list          # list[BlockNode] — dense-block path (Pallas TRSM)


def build_solve_structure(plan: FactorPlan, bulk_min_width: int = 8) -> SolveStructure:
    lu = lu_structure(plan)
    n = plan.n
    l_fwd = tri_schedule(n, lu.l_indptr, lu.l_indices, lu.l_slots, lower=True,
                         bulk_min_width=bulk_min_width)
    u_bwd = tri_schedule(n, lu.u_indptr, lu.u_indices, lu.u_slots, lower=False,
                         bulk_min_width=bulk_min_width)
    lt_ip, lt_ix, lt_sl = transpose_csr(n, lu.l_indptr, lu.l_indices, lu.l_slots)
    ut_ip, ut_ix, ut_sl = transpose_csr(n, lu.u_indptr, lu.u_indices, lu.u_slots)
    lt_bwd = tri_schedule(n, lt_ip, lt_ix, lt_sl, lower=False,
                          bulk_min_width=bulk_min_width)
    ut_fwd = tri_schedule(n, ut_ip, ut_ix, ut_sl, lower=True,
                          bulk_min_width=bulk_min_width)
    return SolveStructure(n=n, lu=lu, l_fwd=l_fwd, u_bwd=u_bwd,
                          lt_bwd=lt_bwd, ut_fwd=ut_fwd,
                          blocks=block_schedule(plan))
