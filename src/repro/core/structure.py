"""Static L/U structure + slot maps derived from a FactorPlan.

The factored values live in the flat panel buffer ``vals``; every L/U entry
has a *static* slot there (in-node pivoting permutes which original row a
panel row holds, never the slot layout).  These maps let the JAX solve,
transpose-solve (adjoint) and refactorization paths gather L/U values with
compile-time-constant indices.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from .plan import FactorPlan
from .symbolic import Symbolic


# --------------------------------------------------------------------------
# supernode amalgamation (panel fattening under a fill tolerance)
# --------------------------------------------------------------------------
def _node_fill_pattern(sym: Symbolic, r0: int, r1: int) -> np.ndarray:
    """Filled column pattern of the row run [r0, r1): union over its rows of
    {i} ∪ struct(L row i) ∪ struct(U row i) — under the symmetrized pattern
    struct(U row i) beyond the diagonal equals struct(L col i) transposed,
    which the symbolic analysis already carries (``lcol``).  This is the
    partition-independent lower bound of the plan's panel width (merged
    upstream sources can only widen it)."""
    parts = [np.arange(r0, r1, dtype=np.int64)]
    for i in range(r0, r1):
        parts.append(sym.lrow_idx[sym.lrow_ptr[i]:sym.lrow_ptr[i + 1]])
        parts.append(sym.lcol_idx[sym.lcol_ptr[i]:sym.lcol_ptr[i + 1]])
    return np.unique(np.concatenate(parts))


def amalgamate_supernodes(sym: Symbolic, fill_tol: float,
                          max_super: int = 128) -> tuple[Symbolic, dict]:
    """Merge *independent* adjacent supernodes with near-identical column
    patterns into fatter panels (CKTSO-style relaxation, one knob past the
    fundamental / ``relax`` amalgamation of ``symbolic_factorize``).

    A run of consecutive nodes is grown greedily while (a) the candidate
    does not depend on the run — no filled L/U entry couples its rows to
    the run's rows, checked on the filled structures — and (b) the extra
    explicit zeros the merged panel stores — ``(nr_merged × w_merged) − Σ
    separate slots`` — stay within ``fill_tol`` of the run's separate
    storage, and the merged block height stays ≤ ``max_super``.

    Why independence: near-identical adjacent columns in circuit matrices
    are overwhelmingly *sibling* columns (parallel device terminals, tied
    nets) — independent, at the same elimination depth — and merging them
    fattens the level's panels without touching the level structure, so
    the bucketed schedule's long scanned width-1 tail (its compile-time
    lifeline at n≥10^4) survives.  Merging *dependent* chain nodes instead
    collapses levels but converts the scanned tail into thousands of
    unrolled level steps, which does not compile in reasonable time on
    XLA:CPU; dependent parent/child fattening is the existing ``relax``
    knob's job inside ``symbolic_factorize``.

    Structural zeros inside a union pattern carry exact numeric zeros (see
    :mod:`repro.core.plan`), so the coarsening is numerically exact: the
    amalgamated plan factors to the same L/U values and solves
    bit-identically; only panel geometry (node count, pad waste, kernel
    shapes) changes.

    Returns the coarsened ``Symbolic`` plus a stats dict
    (``n_nodes_before/after``, ``n_merges``, ``est_extra_slots``,
    ``est_base_slots``, ``fill_tol``).  ``fill_tol <= 0`` returns the input
    partition unchanged (and the stats record zero merges), so the default
    plan is bit-for-bit the historical one."""
    starts, ends = sym.snode_start, sym.snode_end
    n_nodes = len(starts)
    base_slots = 0
    stats = dict(n_nodes_before=int(n_nodes), n_nodes_after=int(n_nodes),
                 n_merges=0, est_extra_slots=0, est_base_slots=0,
                 fill_tol=float(fill_tol))
    if fill_tol <= 0 or n_nodes <= 1:
        return sym, stats

    new_starts = []
    est_extra = 0
    n_merges = 0
    cur_r0, cur_r1 = int(starts[0]), int(ends[0])
    cur_pat = _node_fill_pattern(sym, cur_r0, cur_r1)
    cur_sep = (cur_r1 - cur_r0) * len(cur_pat)   # separate-storage sum of run
    base_slots = cur_sep

    def _close_run():
        nonlocal est_extra
        new_starts.append(cur_r0)
        est_extra += (cur_r1 - cur_r0) * len(cur_pat) - cur_sep

    for t in range(1, n_nodes):
        r0, r1 = int(starts[t]), int(ends[t])
        pat_t = _node_fill_pattern(sym, r0, r1)
        sep_t = (r1 - r0) * len(pat_t)
        base_slots += sep_t
        nr_m = r1 - cur_r0
        if nr_m <= max_super:
            # independence: the candidate's pattern must not reach back
            # into the run's rows (entries < r0 in pat_t are exactly its
            # filled L-row structure = its in-factor dependencies), and
            # the run's pattern must not reach into the candidate's rows
            lo = np.searchsorted(pat_t, cur_r0)
            hi = np.searchsorted(pat_t, r0)
            lo2 = np.searchsorted(cur_pat, r0)
            hi2 = np.searchsorted(cur_pat, r1)
            if lo == hi and lo2 == hi2:
                pat_m = np.union1d(cur_pat, pat_t)
                extra = nr_m * len(pat_m) - (cur_sep + sep_t)
                if extra <= fill_tol * (cur_sep + sep_t):
                    cur_pat, cur_r1 = pat_m, r1
                    cur_sep += sep_t
                    n_merges += 1
                    continue
        _close_run()
        cur_r0, cur_r1, cur_pat, cur_sep = r0, r1, pat_t, sep_t
    _close_run()

    new_starts = np.asarray(new_starts, dtype=np.int64)
    new_ends = np.append(new_starts[1:], sym.n)
    snode_of = np.zeros(sym.n, dtype=np.int64)
    for t in range(len(new_starts)):
        snode_of[new_starts[t]:new_ends[t]] = t
    stats.update(n_nodes_after=len(new_starts), n_merges=int(n_merges),
                 est_extra_slots=int(est_extra),
                 est_base_slots=int(base_slots))
    out = dataclasses.replace(sym, snode_of=snode_of,
                              snode_start=new_starts, snode_end=new_ends)
    return out, stats


@dataclasses.dataclass
class LUStructure:
    n: int
    # L strictly-lower (unit diag implicit), CSR by rows
    l_indptr: np.ndarray
    l_indices: np.ndarray
    l_slots: np.ndarray
    # U strictly-upper, CSR by rows, diag separate
    u_indptr: np.ndarray
    u_indices: np.ndarray
    u_slots: np.ndarray
    u_diag_slots: np.ndarray


def lu_structure(plan: FactorPlan) -> LUStructure:
    n = plan.n
    lr_pt = [0]; lr_ix = []; lr_sl = []
    ur_pt = [0]; ur_ix = []; ur_sl = []
    ud_sl = np.empty(n, dtype=np.int64)
    for nd in plan.nodes:
        off = int(plan.panel_offset[nd.nid])
        nr, w, ls = nd.nr, nd.width, nd.lsize
        pat = nd.pattern
        for q in range(nr):
            g = nd.r0 + q
            base = off + q * w
            # L: prefix cols + in-block strictly-lower
            lr_ix.extend(pat[:ls].tolist())
            lr_sl.extend(range(base, base + ls))
            lr_ix.extend(range(nd.r0, nd.r0 + q))
            lr_sl.extend(range(base + ls, base + ls + q))
            lr_pt.append(len(lr_ix))
            # U: strictly-upper in-block + suffix; diag separate
            ud_sl[g] = base + ls + q
            ur_ix.extend(range(g + 1, nd.r0 + nr))
            ur_sl.extend(range(base + ls + q + 1, base + ls + nr))
            ur_ix.extend(pat[ls + nr:].tolist())
            ur_sl.extend(range(base + ls + nr, base + w))
            ur_pt.append(len(ur_ix))
    return LUStructure(
        n=n,
        l_indptr=np.array(lr_pt, dtype=np.int64),
        l_indices=np.array(lr_ix, dtype=np.int64),
        l_slots=np.array(lr_sl, dtype=np.int64),
        u_indptr=np.array(ur_pt, dtype=np.int64),
        u_indices=np.array(ur_ix, dtype=np.int64),
        u_slots=np.array(ur_sl, dtype=np.int64),
        u_diag_slots=ud_sl,
    )


def transpose_csr(n, indptr, indices, slots):
    """CSC view == CSR of the transpose, keeping slot association."""
    rows = np.repeat(np.arange(n), np.diff(indptr))
    order = np.lexsort((rows, indices))
    t_rows = indices[order]
    t_cols = rows[order]
    t_slots = slots[order]
    t_indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(t_indptr, t_rows + 1, 1)
    return np.cumsum(t_indptr), t_cols, t_slots


@dataclasses.dataclass
class TriSched:
    """Level schedule for one triangular solve, flattened per level.
    Per level k: rows[k] (unknowns finalized), cols[k]/slot[k]/seg[k]
    (dependencies; slot indexes the flat panel buffer)."""
    rows: list
    cols: list
    slot: list
    seg: list
    n_bulk: int
    n_levels: int


def tri_schedule(n, indptr, indices, slots, lower: bool,
                 bulk_min_width: int = 8) -> TriSched:
    """Levelize a triangular system given as strictly-tri CSR. ``lower``
    selects dependency direction (forward vs backward substitution)."""
    lev = np.zeros(n, dtype=np.int64)
    rng = range(n) if lower else range(n - 1, -1, -1)
    for i in rng:
        s, e = indptr[i], indptr[i + 1]
        if e > s:
            lev[i] = 1 + lev[indices[s:e]].max()
    nl = int(lev.max()) + 1 if n else 0
    rows_l, cols_l, slot_l, seg_l = [], [], [], []
    n_bulk = 0
    for k in range(nl):
        rows = np.where(lev == k)[0]
        cnt = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
        seg = np.repeat(np.arange(len(rows)), cnt)
        take = (np.concatenate([np.arange(indptr[i], indptr[i + 1]) for i in rows])
                if cnt.sum() else np.empty(0, np.int64))
        rows_l.append(rows); cols_l.append(indices[take])
        slot_l.append(slots[take]); seg_l.append(seg)
        if len(rows) >= bulk_min_width:
            n_bulk += 1
    return TriSched(rows_l, cols_l, slot_l, seg_l, n_bulk, nl)


@dataclasses.dataclass
class BlockNode:
    """Static per-node gather maps for the block (panel) substitution path.

    The dense diagonal block (``blk_slots``) and the off-block L-prefix /
    U-suffix rectangles are read straight out of the flat panel buffer with
    these compile-time index matrices, so a solve can run node-by-node as
    dense GEMV/TRSM ops — the shape the Pallas TRSM kernel wants — instead
    of row-by-row levels."""
    r0: int
    nr: int
    pre_cols: np.ndarray    # (lsize,)  global cols of the L prefix
    pre_slots: np.ndarray   # (nr, lsize) flat slots of the L prefix
    suf_cols: np.ndarray    # (usize,)  global cols of the U suffix
    suf_slots: np.ndarray   # (nr, usize) flat slots of the U suffix
    blk_slots: np.ndarray   # (nr, nr) flat slots of the dense diagonal block
                            # (strict lower = L values, upper incl. diag = U)


def block_schedule(plan: FactorPlan) -> list:
    """Per-node block maps, ascending r0 (forward L order; reverse for U)."""
    nodes = []
    for nd in plan.nodes:
        off = int(plan.panel_offset[nd.nid])
        nr, w, ls = nd.nr, nd.width, nd.lsize
        row = off + np.arange(nr, dtype=np.int64)[:, None] * w
        nodes.append(BlockNode(
            r0=nd.r0, nr=nr,
            pre_cols=nd.pattern[:ls].astype(np.int64),
            pre_slots=row + np.arange(ls, dtype=np.int64)[None, :],
            suf_cols=nd.pattern[ls + nr:].astype(np.int64),
            suf_slots=row + ls + nr + np.arange(nd.usize, dtype=np.int64)[None, :],
            blk_slots=row + ls + np.arange(nr, dtype=np.int64)[None, :],
        ))
    return nodes


# --------------------------------------------------------------------------
# level-bucketed factorization schedule: the O(levels) trace data structure
# --------------------------------------------------------------------------
#
# The unrolled jax factor path emits O(nodes + edges) XLA ops, which caps
# compile time at toy sizes.  This schedule regroups the plan's level
# schedule into static shape buckets so the traced program is
# O(levels × shape-buckets):
#
#   per level ℓ (ascending):
#     1. internal factorization of every level-ℓ node — width-1 nodes are one
#        vectorized diagonal perturbation (DiagBucket); wider nodes on wide
#        (bulk) levels are one vmapped dense panel LU per padded shape
#        (PanelBucket); wider nodes on narrow levels keep the per-node dense
#        panel LU (the paper's sequential mode — `seq` on the step);
#     2. application of every edge OUT of level-ℓ sources — one batched
#        gather + TRSM + GEMM + scatter per padded shape (EdgeBucket).
#        Edges are bucketed on *every* level, narrow ones included: in a
#        sparse LU the late narrow levels own the densest edge lists, so
#        leaving them unrolled would keep the trace O(edges).
#
# Correctness of the right-looking per-level sweep: two same-level nodes
# never share an edge, so (a) an edge's multiplier columns (the source's
# block columns inside the target pattern) receive no further updates once
# the source level is factored, and (b) same-level edges into one target
# touch disjoint multiplier columns and purely-additive trailing columns.
# The gathered values therefore equal the left-looking ones exactly; only
# the floating-point summation order of trailing updates differs.
#
# Padding never changes the arithmetic: index matrices point padded gather
# positions at a constant 0-slot (or an identity-pivot sentinel slot — a
# huge constant that behaves as an un-pickable, never-"small" pivot and
# divides padded zeros to exact zeros — on padded block diagonals, so
# padded pivots are exact identity no-ops) and padded scatter positions at a
# write-only scratch slot.  All three live past the end of the value buffer.
_PAD_ZERO, _PAD_ONE, _PAD_SCRATCH = 0, 1, 2     # offsets past total_slots


def _pad_dim(v: int) -> int:
    """Round a bucket dimension up to the next power of two (small static
    shape vocabulary → few distinct traced subcomputations)."""
    return 1 if v <= 1 else int(2 ** np.ceil(np.log2(v)))


def _pad8(v: int) -> int:
    """Round a merged (max-within-bucket) dimension up to a sublane
    multiple; 0 stays 0 (empty part)."""
    return 0 if v <= 0 else -(-v // 8) * 8


def segment_levels(dims: list, max_groups: int = 12) -> list:
    """Partition an ordered list of per-level dimension tuples into runs
    whose per-dim max/min ratio is bounded — the shared chunking heuristic
    of the factor scan and the tri-solve scan.

    Group count is trace size, so the allowed pad ratio escalates (4, 16,
    64, …) until at most ``max_groups`` runs remain; padded work on the
    tiny narrow-tail levels stays negligible.  Returns a list of
    (start, end) index pairs (end exclusive)."""
    dims = [tuple(max(int(d), 1) for d in t) for t in dims]

    def _segment(ratio):
        groups = []
        i = 0
        while i < len(dims):
            j = i
            lo = hi = None
            while j < len(dims):
                d = dims[j]
                if lo is None:
                    lo, hi = d, d
                else:
                    nlo = tuple(min(a, b) for a, b in zip(lo, d))
                    nhi = tuple(max(a, b) for a, b in zip(hi, d))
                    if any(h > ratio * l for l, h in zip(nlo, nhi)):
                        break
                    lo, hi = nlo, nhi
                j += 1
            groups.append((i, j))
            i = j
        return groups

    ratio = 4
    groups = _segment(ratio)
    while len(groups) > max_groups and ratio < 1 << 30:
        ratio *= 4
        groups = _segment(ratio)
    return groups


@dataclasses.dataclass
class DiagBucket:
    """All width-1 nodes of one level: internal LU degenerates to pivot
    perturbation of the diagonal slot."""
    level: int
    nids: np.ndarray        # (B,)
    slots: np.ndarray       # (B,) flat diagonal slots


@dataclasses.dataclass
class PanelBucket:
    """Width>1 nodes of one level sharing a padded panel shape.

    The gathered panel is column-reordered to [diagonal block | U suffix |
    L prefix] so the elimination window is the static range [0, wu) for
    every node regardless of its lsize; the L prefix rides along at the end
    purely so in-block row pivoting permutes it too."""
    level: int
    nr: int                 # padded block rows
    wu: int                 # elimination width: padded nr + padded usize
    wt: int                 # gathered width: wu + padded lsize
    nids: np.ndarray        # (B,)
    gather: np.ndarray      # (B, nr, wt) flat slots (pads → 0/1 slots)
    scatter: np.ndarray     # (B, nr, wt) flat slots (pads → scratch)
    rows: np.ndarray        # (B, nr) global row ids (pads → n)


@dataclasses.dataclass
class EdgeBucket:
    """Edges out of one level's sources sharing a padded (k, nr, m) shape:
    one batched TRSM + GEMM and ONE combined scatter-add per bucket.

    The multiplier columns' ``.set(lts)`` is expressed as ``.add(lts - X)``
    (their pre-update value is exactly the gathered X — no other same-level
    edge touches them), so multiplier write-back and trailing update fuse
    into a single duplicate-accumulating scatter over ``write_idx`` —
    XLA:CPU compile time is dominated by scatter op count."""
    src_level: int
    k: int                  # padded source block width
    nr: int                 # padded target rows
    m: int                  # padded source U-suffix width
    srcs: np.ndarray        # (E,) source nids
    tgts: np.ndarray        # (E,) target nids
    src_idx: np.ndarray     # (E, k, k+m) source rows [diag block | U suffix]
                            # (block-diagonal pads → 1, others → 0)
    x_idx: np.ndarray       # (E, nr, k) target multiplier columns (pads → 0)
    write_idx: np.ndarray   # (E, nr*(k+m)) combined scatter: first nr*k
                            # entries are the multiplier positions, the rest
                            # the trailing positions (pads → scratch)


@dataclasses.dataclass
class LevelStep:
    level: int
    diag: DiagBucket | None
    panels: list            # list[PanelBucket]
    seq: np.ndarray         # node ids factored per-node (narrow-level wide
                            # nodes); their edges are still bucketed
    edges: list             # list[EdgeBucket]


@dataclasses.dataclass
class ScanChunk:
    """A run of consecutive all-width-1 levels executed as ONE ``lax.scan``
    whose body is traced once — the trace-size endgame for the long narrow
    tail of circuit-style level schedules.

    All levels in the chunk are padded to shared (D, E, M) shapes; the
    sentinel slots make the padding maskless (padded diagonal slots read
    the huge identity-pivot sentinel — never "small", rewritten verbatim;
    padded gathers read 0 → zero multipliers and zero updates; padded
    writes land in scratch)."""
    lv0: int
    lv1: int                # exclusive
    dsl: np.ndarray         # (L, D) diag slots, pads → one slot
    x_idx: np.ndarray       # (L, E) multiplier gathers, pads → zero slot
    src_idx: np.ndarray     # (L, E, 1+M) source rows [diag | U], pads:
                            # col 0 → one slot, cols 1: → zero slot
    write_idx: np.ndarray   # (L, E, 1+M) combined scatter, pads → scratch


@dataclasses.dataclass
class BucketSchedule:
    n: int
    total_slots: int
    n_ext: int              # total_slots + 3 (zero / one / scratch slots)
    zero_slot: int
    one_slot: int
    scratch_slot: int
    n_bulk_levels: int
    steps: list             # list[LevelStep], unrolled level prefix
    scan_chunks: list       # list[ScanChunk], the scanned width-1 suffix


def build_bucket_schedule(plan: FactorPlan,
                          bulk_min_width: int = 8) -> BucketSchedule:
    """Pre-flatten the plan's level schedule into static per-(level, shape)
    index arrays (see module comment above for the execution semantics).
    ``bulk_min_width`` is the dual-mode threshold: levels with fewer nodes
    run their wide-node internal LUs per-node (sequential mode)."""
    nodes = plan.nodes
    offs = plan.panel_offset
    n, n_nodes = plan.n, plan.n_nodes
    total = plan.total_slots
    assert total + 3 < np.iinfo(np.int32).max, "plan too large for int32 maps"
    zero, one, scr = (total + _PAD_ZERO, total + _PAD_ONE,
                      total + _PAD_SCRATCH)

    # ------- group all edges by (source level, padded k/nr class) ----------
    # m (the source U-suffix width) is NOT part of the key: every (level,
    # k, nr) class forms one bucket, padded to its max m, and is then split
    # only where padding waste would exceed 4x (``_waste_split``).  Bucket
    # count — i.e. trace size — is what compile time scales with; bounded
    # m-padding waste is just zero lanes through the gather/GEMM/scatter.
    edge_groups: dict = {}
    for nd in nodes:
        for e in nd.edges:
            snd = nodes[e.src]
            key = (snd.level, _pad_dim(snd.nr), _pad_dim(nd.nr))
            edge_groups.setdefault(key, []).append((e, nd))

    def _edge_m(pair):
        e, _ = pair
        return len(e.col_map) - nodes[e.src].nr

    def _waste_split(pairs, ratio=4):
        """Split a bucket's edge list into runs whose max/min m ratio is
        bounded — bounded pad waste at a bounded bucket-count increase."""
        pairs = sorted(pairs, key=_edge_m, reverse=True)
        out, cur = [], [pairs[0]]
        cap = max(_edge_m(pairs[0]), 1)
        for p in pairs[1:]:
            if cap > ratio * max(_edge_m(p), 1):
                out.append(cur)
                cur, cap = [], max(_edge_m(p), 1)
            cur.append(p)
        out.append(cur)
        return out

    def _edge_bucket(key, pairs) -> EdgeBucket:
        lv, kp, nrp = key
        mp = _pad8(max(_edge_m(p) for p in pairs))
        ne = len(pairs)
        src_idx = np.full((ne, kp, kp + mp), zero, dtype=np.int32)
        src_idx[:, np.arange(kp), np.arange(kp)] = one
        x_idx = np.full((ne, nrp, kp), zero, dtype=np.int32)
        lts_idx = np.full((ne, nrp, kp), scr, dtype=np.int32)
        upd_idx = np.full((ne, nrp, mp), scr, dtype=np.int32)
        srcs = np.empty(ne, dtype=np.int64)
        tgts = np.empty(ne, dtype=np.int64)
        for i, (e, nd) in enumerate(pairs):
            snd = nodes[e.src]
            k, m, nr = snd.nr, len(e.col_map) - snd.nr, nd.nr
            srcs[i], tgts[i] = snd.nid, nd.nid
            srow = (offs[snd.nid] + snd.lsize
                    + np.arange(k, dtype=np.int64)[:, None] * snd.width)
            src_idx[i, :k, :k] = srow + np.arange(k)[None, :]
            src_idx[i, :k, kp:kp + m] = srow + k + np.arange(m)[None, :]
            trow = (offs[nd.nid]
                    + np.arange(nr, dtype=np.int64)[:, None] * nd.width)
            x_idx[i, :nr, :k] = trow + e.col_map[None, :k]
            lts_idx[i, :nr, :k] = trow + e.col_map[None, :k]
            upd_idx[i, :nr, :m] = trow + e.col_map[None, k:]
        write_idx = np.concatenate([lts_idx.reshape(ne, -1),
                                    upd_idx.reshape(ne, -1)], axis=1)
        return EdgeBucket(src_level=lv, k=kp, nr=nrp, m=mp, srcs=srcs,
                          tgts=tgts, src_idx=src_idx, x_idx=x_idx,
                          write_idx=write_idx)

    def _panel_bucket(lv, nrp, nids) -> PanelBucket:
        usp = _pad8(max(nodes[t].usize for t in nids))
        lsp = _pad8(max(nodes[t].lsize for t in nids))
        wu, wt = nrp + usp, nrp + usp + lsp
        nbk = len(nids)
        gather = np.full((nbk, nrp, wt), zero, dtype=np.int32)
        gather[:, np.arange(nrp), np.arange(nrp)] = one   # identity diag pads
        scatter = np.full((nbk, nrp, wt), scr, dtype=np.int32)
        rows = np.full((nbk, nrp), n, dtype=np.int32)
        for i, t in enumerate(nids):
            nd = nodes[t]
            nr, w, ls, us = nd.nr, nd.width, nd.lsize, nd.usize
            base = (offs[t]
                    + np.arange(nr, dtype=np.int64)[:, None] * w)
            # column-reordered [block | suffix | prefix] slot map
            cols = np.concatenate([ls + np.arange(nr),            # block
                                   np.full(nrp - nr, -1),         # diag pads
                                   ls + nr + np.arange(us),       # suffix
                                   np.full(usp - us, -1),
                                   np.arange(ls),                 # prefix
                                   np.full(lsp - ls, -1)])
            real = cols >= 0
            slots = base + cols[real][None, :]                    # (nr, n_real)
            gather[i][:nr, real] = slots
            scatter[i][:nr, real] = slots
            rows[i, :nr] = nd.r0 + np.arange(nr)
        return PanelBucket(level=lv, nr=nrp, wu=wu, wt=wt,
                           nids=np.asarray(nids, dtype=np.int64),
                           gather=gather, scatter=scatter, rows=rows)

    # ------- scannable suffix: maximal run of all-width-1 levels -----------
    # (sources AND targets width 1 — target levels of a suffix edge are
    # later levels, themselves in the suffix, so checking node widths per
    # level suffices).  These levels' work collapses to one lax.scan body
    # per chunk instead of one traced step per level.
    n_levels = len(plan.levels)
    scan_start = n_levels
    while (scan_start > 0
           and all(nodes[int(t)].nr == 1
                   for t in plan.levels[scan_start - 1])
           and len(plan.levels[scan_start - 1]) < bulk_min_width):
        scan_start -= 1

    steps = []
    for lv in range(scan_start):
        nids = plan.levels[lv]
        bulk = len(nids) >= bulk_min_width
        ones = [int(t) for t in nids if nodes[t].nr == 1]
        diag = None
        if ones:
            diag = DiagBucket(
                level=lv, nids=np.asarray(ones, dtype=np.int64),
                slots=plan.row_perm_slots[
                    [nodes[t].r0 for t in ones]].astype(np.int32))
        wide = [int(t) for t in nids if nodes[t].nr > 1]
        panels, seq = [], []
        if bulk:
            wide_groups: dict = {}
            for t in wide:
                wide_groups.setdefault(_pad_dim(nodes[t].nr), []).append(t)
            panels = [_panel_bucket(lv, nrp, nids_g)
                      for nrp, nids_g in sorted(wide_groups.items())]
        else:
            seq = wide
        edges = [_edge_bucket(key, sub)
                 for key, pairs in sorted(edge_groups.items(),
                                          key=lambda kv: kv[0])
                 if key[0] == lv
                 for sub in _waste_split(pairs)]
        steps.append(LevelStep(level=lv, diag=diag, panels=panels,
                               seq=np.asarray(seq, dtype=np.int64),
                               edges=edges))

    # ------- scan chunks over the width-1 suffix ---------------------------
    def _level_raw(lv):
        """(diag_slots, [(x, src_row_base, m, col_map, toff, tw)]) of one
        scanned level — everything is width 1."""
        dsl = plan.row_perm_slots[
            [nodes[int(t)].r0 for t in plan.levels[lv]]].astype(np.int64)
        epairs = []
        for key, pairs in edge_groups.items():
            if key[0] == lv:
                epairs.extend(pairs)
        return dsl, epairs

    raw = {lv: _level_raw(lv) for lv in range(scan_start, n_levels)}

    def _dims(lv):
        dsl, epairs = raw[lv]
        return (len(dsl), len(epairs),
                max((_edge_m(p) for p in epairs), default=0))

    groups = [(i + scan_start, j + scan_start)
              for i, j in segment_levels(
                  [_dims(lv) for lv in range(scan_start, n_levels)])]

    chunks = []
    for lv0, lv1 in groups:
        dmax, emax, mmax = (max(max(vs), 1) for vs in zip(
            *(_dims(lv) for lv in range(lv0, lv1))))
        L = lv1 - lv0
        dsl_a = np.full((L, dmax), one, dtype=np.int32)
        x_a = np.full((L, emax), zero, dtype=np.int32)
        src_a = np.full((L, emax, 1 + mmax), zero, dtype=np.int32)
        src_a[:, :, 0] = one
        wr_a = np.full((L, emax, 1 + mmax), scr, dtype=np.int32)
        for l, lvx in enumerate(range(lv0, lv1)):
            dsl, epairs = raw[lvx]
            dsl_a[l, :len(dsl)] = dsl
            for i, (e, nd) in enumerate(epairs):
                snd = nodes[e.src]
                m = len(e.col_map) - 1
                srow = offs[snd.nid] + snd.lsize
                src_a[l, i, 0] = srow
                src_a[l, i, 1:1 + m] = srow + 1 + np.arange(m)
                toff = offs[nd.nid]
                x_a[l, i] = toff + e.col_map[0]
                wr_a[l, i, 0] = toff + e.col_map[0]
                wr_a[l, i, 1:1 + m] = toff + e.col_map[1:]
        chunks.append(ScanChunk(lv0=lv0, lv1=lv1, dsl=dsl_a, x_idx=x_a,
                                src_idx=src_a, write_idx=wr_a))

    return BucketSchedule(n=n, total_slots=total, n_ext=total + 3,
                          zero_slot=zero, one_slot=one, scratch_slot=scr,
                          n_bulk_levels=plan.n_bulk_levels, steps=steps,
                          scan_chunks=chunks)


def get_bucket_schedule(plan: FactorPlan,
                        bulk_min_width: int = 8) -> BucketSchedule:
    """Build-once cache of the bucket schedule on the plan object."""
    cache = getattr(plan, "_bucket_schedules", None)
    if cache is None:
        cache = {}
        plan._bucket_schedules = cache
    sched = cache.get(bulk_min_width)
    if sched is None:
        sched = build_bucket_schedule(plan, bulk_min_width=bulk_min_width)
        cache[bulk_min_width] = sched
    return sched


def bucket_stats(plan: FactorPlan, bulk_min_width: int = 8) -> dict:
    """Bucket-count / padding statistics of the bucketed factor schedule
    (consumed by ``plan.plan_stats`` so kernel_select thresholds can be
    revisited against real pad-waste numbers)."""
    sched = get_bucket_schedule(plan, bulk_min_width=bulk_min_width)
    n_panel = sum(len(s.panels) for s in sched.steps)
    n_diag = sum(1 for s in sched.steps if s.diag is not None)
    n_edge = sum(len(s.edges) for s in sched.steps)
    n_seq = sum(len(s.seq) for s in sched.steps)
    n_scanned = sum(c.lv1 - c.lv0 for c in sched.scan_chunks)
    gathered = 0
    real = 0
    for s in sched.steps:
        for pb in s.panels:
            gathered += pb.gather.size
            real += int((pb.gather < sched.total_slots).sum())
        for eb in s.edges:
            for arr in (eb.src_idx, eb.x_idx, eb.write_idx):
                gathered += arr.size
                real += int((arr < sched.total_slots).sum())
    for c in sched.scan_chunks:
        for arr in (c.dsl, c.x_idx, c.src_idx, c.write_idx):
            gathered += arr.size
            real += int((arr < sched.total_slots).sum())
    return dict(
        n_seq_nodes=n_seq,
        n_diag_buckets=n_diag,
        n_panel_buckets=n_panel,
        n_edge_buckets=n_edge,
        n_scan_chunks=len(sched.scan_chunks),
        n_scanned_levels=n_scanned,
        bulk_node_coverage=1.0 - n_seq / max(plan.n_nodes, 1),
        pad_waste_frac=(gathered - real) / max(gathered, 1),
    )


@dataclasses.dataclass
class SolveStructure:
    """Everything the JAX solve/adjoint needs, all static."""
    n: int
    lu: LUStructure
    l_fwd: TriSched       # L y = c      (forward)
    u_bwd: TriSched       # U w = y      (backward)
    lt_bwd: TriSched      # Lᵀ w = y     (backward; adjoint path)
    ut_fwd: TriSched      # Uᵀ y = c     (forward;  adjoint path)
    blocks: list          # list[BlockNode] — dense-block path (Pallas TRSM)


def build_solve_structure(plan: FactorPlan, bulk_min_width: int = 8) -> SolveStructure:
    lu = lu_structure(plan)
    n = plan.n
    l_fwd = tri_schedule(n, lu.l_indptr, lu.l_indices, lu.l_slots, lower=True,
                         bulk_min_width=bulk_min_width)
    u_bwd = tri_schedule(n, lu.u_indptr, lu.u_indices, lu.u_slots, lower=False,
                         bulk_min_width=bulk_min_width)
    lt_ip, lt_ix, lt_sl = transpose_csr(n, lu.l_indptr, lu.l_indices, lu.l_slots)
    ut_ip, ut_ix, ut_sl = transpose_csr(n, lu.u_indptr, lu.u_indices, lu.u_slots)
    lt_bwd = tri_schedule(n, lt_ip, lt_ix, lt_sl, lower=False,
                          bulk_min_width=bulk_min_width)
    ut_fwd = tri_schedule(n, ut_ip, ut_ix, ut_sl, lower=True,
                          bulk_min_width=bulk_min_width)
    return SolveStructure(n=n, lu=lu, l_fwd=l_fwd, u_bwd=u_bwd,
                          lt_bwd=lt_bwd, ut_fwd=ut_fwd,
                          blocks=block_schedule(plan))
