"""Host-side sparse matrix container (CSR) used by the analysis phase.

All preprocessing (matching, ordering, symbolic factorization) is host/graph
work and runs in numpy — this mirrors production TPU deployments where the
analysis phase runs on the host CPU and only the numeric phase runs on the
accelerator.
"""
from __future__ import annotations

import dataclasses
import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed-sparse-row matrix. indices within each row are sorted."""

    n: int
    indptr: np.ndarray   # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32/int64, sorted per row
    data: np.ndarray     # (nnz,) float64

    # ---------------------------------------------------------------- build
    @staticmethod
    def from_coo(n: int, rows, cols, vals, sum_dup: bool = True) -> "CSR":
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals, dtype=np.float64)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        if sum_dup and len(rows):
            key = rows * n + cols
            uniq, inv = np.unique(key, return_inverse=True)
            out = np.zeros(len(uniq), dtype=np.float64)
            np.add.at(out, inv, vals)
            rows, cols, vals = uniq // n, uniq % n, out
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(n, indptr, cols.astype(np.int64), vals)

    @staticmethod
    def from_dense(a: np.ndarray) -> "CSR":
        a = np.asarray(a, dtype=np.float64)
        n = a.shape[0]
        rows, cols = np.nonzero(a)
        return CSR.from_coo(n, rows, cols, a[rows, cols], sum_dup=False)

    @staticmethod
    def from_scipy(a) -> "CSR":
        a = a.tocsr()
        a.sort_indices()
        return CSR(a.shape[0], a.indptr.astype(np.int64),
                   a.indices.astype(np.int64), a.data.astype(np.float64))

    # ---------------------------------------------------------------- props
    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row(self, i: int):
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.n, self.n))
        for i in range(self.n):
            idx, val = self.row(i)
            a[i, idx] = val
        return a

    def to_scipy(self):
        import scipy.sparse as sp
        return sp.csr_matrix((self.data, self.indices, self.indptr),
                             shape=(self.n, self.n))

    def matvec(self, x: np.ndarray) -> np.ndarray:
        seg = np.repeat(np.arange(self.n), np.diff(self.indptr))
        out = np.zeros(self.n)
        np.add.at(out, seg, self.data * x[self.indices])
        return out

    # ----------------------------------------------------------- transforms
    def transpose(self) -> "CSR":
        seg = np.repeat(np.arange(self.n), np.diff(self.indptr))
        return CSR.from_coo(self.n, self.indices, seg, self.data, sum_dup=False)

    def permute(self, p_row: np.ndarray, p_col: np.ndarray) -> "CSR":
        """Return B with B[i, j] = A[p_row[i], p_col[j]]."""
        inv_col = np.empty(self.n, dtype=np.int64)
        inv_col[p_col] = np.arange(self.n)
        seg = np.repeat(np.arange(self.n), np.diff(self.indptr))
        inv_row = np.empty(self.n, dtype=np.int64)
        inv_row[p_row] = np.arange(self.n)
        return CSR.from_coo(self.n, inv_row[seg], inv_col[self.indices],
                            self.data, sum_dup=False)

    def scale(self, dr: np.ndarray, dc: np.ndarray) -> "CSR":
        """Return diag(dr) @ A @ diag(dc)."""
        seg = np.repeat(np.arange(self.n), np.diff(self.indptr))
        return CSR(self.n, self.indptr.copy(), self.indices.copy(),
                   self.data * dr[seg] * dc[self.indices])

    def sym_pattern(self) -> "CSR":
        """Pattern of A + A^T + I (data = 1.0)."""
        seg = np.repeat(np.arange(self.n), np.diff(self.indptr))
        rows = np.concatenate([seg, self.indices, np.arange(self.n)])
        cols = np.concatenate([self.indices, seg, np.arange(self.n)])
        return CSR.from_coo(self.n, rows, cols, np.ones(len(rows)), sum_dup=True)
