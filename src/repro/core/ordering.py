"""Fill-reducing orderings (HYLU preprocessing step 2).

HYLU adopts AMD, a modified AMD, and a modified METIS-based nested dissection,
selected adaptively.  We implement the same *selection* structure with:

  - ``min_degree``   — quotient-graph minimum-degree with element absorption
                       (the AMD family; we use exact external degrees instead of
                       AMD's degree upper bound — the approximation exists to
                       save CPU time, not to improve quality, and numpy set ops
                       make exact degrees affordable at our scales)
  - ``rcm``          — reverse Cuthill–McKee (cheap bandwidth ordering)
  - ``nested_dissection`` — level-set (George) recursive bisection with
                       min-degree leaves: the METIS substitute
  - ``natural``      — identity

``select_ordering`` runs the candidates, computes the symbolic factorization
cost of each (via the elimination tree; see symbolic.py) and returns the
cheapest — this mirrors HYLU's "select based on symbolic statistics".
"""
from __future__ import annotations

import heapq
import numpy as np

from .matrix import CSR


# --------------------------------------------------------------------------
# adjacency helpers (pattern CSR assumed symmetric with diagonal)
# --------------------------------------------------------------------------
def _adj_lists(pat: CSR):
    """Adjacency (excluding diagonal) as list of np arrays."""
    adj = []
    for i in range(pat.n):
        idx, _ = pat.row(i)
        adj.append(idx[idx != i].astype(np.int64))
    return adj


# --------------------------------------------------------------------------
# minimum degree (quotient graph, element absorption)
# --------------------------------------------------------------------------
def min_degree(pat: CSR) -> np.ndarray:
    """Return permutation ``order`` (order[k] = k-th pivot).

    Quotient-graph minimum degree with element absorption and the genuine
    AMD approximate external degree (Amestoy-Davis-Duff):

        d̂_i = |A_i'| + |L_p \\ i| + Σ_{e∋i, e≠p} |L_e \\ L_p|

    where every |L_e \\ L_p| (the w(e) counters) is computed for all touched
    elements in one decrementing pass over L_p — the trick that makes AMD
    fast. Elements with w(e)==0 are absorbed into the new element."""
    n = pat.n
    adj = _adj_lists(pat)
    elems_of: list[list[int]] = [[] for _ in range(n)]
    L: dict[int, np.ndarray] = {}
    alive = np.ones(n, dtype=bool)
    deg = np.array([len(a) for a in adj], dtype=np.int64)
    heap = [(int(deg[i]), i) for i in range(n)]
    heapq.heapify(heap)
    order = np.empty(n, dtype=np.int64)
    lp_mask = np.zeros(n, dtype=bool)

    for k in range(n):
        while True:
            d, p = heapq.heappop(heap)
            if alive[p] and d <= deg[p]:
                break
        # L_p = (A_p ∪ ⋃_{e∋p} L_e) \ {p}, alive vars only
        elems_of[p] = [e for e in elems_of[p] if e in L]
        parts = [adj[p][alive[adj[p]]]] + [L[e] for e in elems_of[p]]
        lp = np.unique(np.concatenate(parts)) if parts else np.empty(0, np.int64)
        lp = lp[(lp != p) & alive[lp]]
        order[k] = p
        alive[p] = False
        for e in elems_of[p]:
            del L[e]                       # absorbed into element p
        L[p] = lp
        lsize = len(lp)
        lp_mask[lp] = True
        # --- w(e) = |L_e \ L_p| in one decrementing pass ------------------
        w: dict[int, int] = {}
        for i in lp:
            lst = elems_of[int(i)]
            for e in lst:
                if e in L:
                    if e not in w:
                        w[e] = len(L[e])
                    w[e] -= 1
        # absorb elements fully covered by the new one
        for e, we in w.items():
            if we <= 0 and e in L:
                del L[e]
        # --- degree updates ----------------------------------------------
        for i in lp:
            i = int(i)
            ai = adj[i]
            ai = ai[alive[ai]]
            ai = ai[~lp_mask[ai]]          # covered by element p now
            adj[i] = ai
            elems = [e for e in elems_of[i] if e in L]
            d_hat = len(ai) + (lsize - 1) + sum(w.get(e, 0) for e in elems)
            elems.append(p)
            elems_of[i] = elems
            deg[i] = max(int(d_hat), 0)
            heapq.heappush(heap, (deg[i], i))
        lp_mask[lp] = False
    return order


# --------------------------------------------------------------------------
# reverse Cuthill–McKee
# --------------------------------------------------------------------------
def _bfs_levels(adj, start, alive_mask=None):
    """Vectorized BFS over list-of-arrays adjacency."""
    n = len(adj)
    level = np.full(n, -1, dtype=np.int64)
    level[start] = 0
    frontier = np.array([start], dtype=np.int64)
    order = [frontier]
    lvl = 0
    while len(frontier):
        nbr = (np.concatenate([adj[int(u)] for u in frontier])
               if len(frontier) else np.empty(0, np.int64))
        nbr = np.unique(nbr)
        nbr = nbr[level[nbr] < 0]
        if alive_mask is not None:
            nbr = nbr[alive_mask[nbr]]
        if not len(nbr):
            break
        lvl += 1
        level[nbr] = lvl
        order.append(nbr)
        frontier = nbr
    return level, np.concatenate(order).tolist()


def _pseudo_peripheral(adj, nodes):
    start = int(nodes[0])
    mask = np.zeros(len(adj), dtype=bool)
    mask[nodes] = True
    for _ in range(4):
        level, order = _bfs_levels(adj, start, mask)
        far = order[-1]
        if level[far] <= level[order[-1]] and far == start:
            break
        if far == start:
            break
        start = far
    return start


def rcm(pat: CSR) -> np.ndarray:
    n = pat.n
    adj = _adj_lists(pat)
    degs = np.array([len(a) for a in adj])
    visited = np.zeros(n, dtype=bool)
    out = []
    for comp_start in range(n):
        if visited[comp_start]:
            continue
        comp_nodes = np.where(~visited)[0]
        start = _pseudo_peripheral(adj, [comp_start])
        # BFS ordering neighbors by degree
        queue = [start]
        visited[start] = True
        while queue:
            u = queue.pop(0)
            out.append(u)
            nbrs = [int(v) for v in adj[u] if not visited[v]]
            nbrs.sort(key=lambda v: degs[v])
            for v in nbrs:
                visited[v] = True
                queue.append(v)
    return np.array(out[::-1], dtype=np.int64)


# --------------------------------------------------------------------------
# nested dissection (level-set bisection, min-degree leaves)
# --------------------------------------------------------------------------
def nested_dissection(pat: CSR, leaf: int = 128) -> np.ndarray:
    n = pat.n
    adj = _adj_lists(pat)
    out: list[int] = []

    def order_sub(nodes: np.ndarray):
        if len(nodes) <= leaf:
            out.extend(_md_sub(adj, nodes))
            return
        mask = np.zeros(n, dtype=bool)
        mask[nodes] = True
        start = _pseudo_peripheral(adj, nodes)
        level, bfs_order = _bfs_levels(adj, start, mask)
        reached = np.array(bfs_order, dtype=np.int64)
        unreached = nodes[level[nodes] < 0]
        if len(reached) <= leaf or level[reached].max() < 2:
            out.extend(_md_sub(adj, nodes))
            return
        mid = int(np.median(level[reached]))
        sep = reached[level[reached] == mid]
        left = reached[level[reached] < mid]
        right = reached[level[reached] > mid]
        if len(left) == 0 or len(right) == 0:
            out.extend(_md_sub(adj, nodes))
            return
        order_sub(np.concatenate([left, unreached]) if len(unreached) else left)
        order_sub(right)
        out.extend(_md_sub(adj, sep))

    order_sub(np.arange(n, dtype=np.int64))
    return np.array(out, dtype=np.int64)


def _md_sub(adj, nodes: np.ndarray):
    """Minimum-degree ordering restricted to ``nodes`` (simple version:
    degrees within the subgraph, no quotient graph — leaves are small)."""
    nodes = np.asarray(nodes, dtype=np.int64)
    if len(nodes) <= 2:
        return nodes.tolist()
    in_sub = {int(v): k for k, v in enumerate(nodes)}
    m = len(nodes)
    nbrs = [set(in_sub[int(v)] for v in adj[int(u)] if int(v) in in_sub)
            for u in nodes]
    alive = [True] * m
    heap = [(len(nbrs[k]), k) for k in range(m)]
    heapq.heapify(heap)
    result = []
    for _ in range(m):
        while True:
            d, k = heapq.heappop(heap)
            if alive[k] and d == len(nbrs[k]):
                break
        alive[k] = False
        result.append(int(nodes[k]))
        clique = [v for v in nbrs[k] if alive[v]]
        for v in clique:
            nbrs[v].discard(k)
            for w in clique:
                if w != v:
                    nbrs[v].add(w)
            heapq.heappush(heap, (len(nbrs[v]), v))
    return result


# --------------------------------------------------------------------------
# adaptive selection
# --------------------------------------------------------------------------
ORDERINGS = {
    "natural": lambda pat: np.arange(pat.n, dtype=np.int64),
    "min_degree": min_degree,
    "rcm": rcm,
    "nested_dissection": nested_dissection,
}


def select_ordering(pat: CSR, candidates=("min_degree", "nested_dissection",
                                          "natural"), return_all=False):
    """Run candidate orderings, score each by predicted factorization FLOPs
    (from elimination-tree column counts) and return the winner.

    Mirrors HYLU's preprocessing: "AMD ... and a modified nested dissection
    ... are adopted for reordering" + selection by symbolic statistics.
    Fill counting aborts early once a candidate exceeds the best fill so
    far (a hopeless 'natural' ordering never pays its full O(fill) walk).
    """
    from .symbolic import etree_col_counts
    best = None
    best_fill = None
    scores = {}
    for name in candidates:
        perm = ORDERINGS[name](pat)
        ppat = pat.permute(perm, perm)
        cc = etree_col_counts(ppat, abort_nnz=(4 * best_fill + 16)
                              if best_fill is not None else None)
        flops = float(np.sum(2.0 * cc.astype(np.float64) ** 2))
        fill = float(cc.sum())
        scores[name] = (flops, fill)
        if best is None or flops < best[1]:
            best = (name, flops, perm)
            best_fill = fill
    if return_all:
        return best[2], best[0], scores
    return best[2], best[0]
