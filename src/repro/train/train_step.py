"""train_step / loss: next-token CE (+ MoE aux), remat, microbatching.

The returned step functions are pure and jit-able; the launcher applies
in/out shardings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_grads

F32 = jnp.float32

MOE_LB_COEF = 0.01
MOE_Z_COEF = 1e-3


def loss_fn(cfg: ArchConfig, params, batch, seq_chunk=512, constrain=None):
    hidden, aux, _ = T.forward(
        cfg, params,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        positions=batch.get("positions"),
        constrain=constrain,
    )
    loss = T.ce_loss_chunked(cfg, params, hidden, batch["labels"],
                             seq_chunk=seq_chunk)
    total = loss
    if "moe_lb" in aux:
        total = total + MOE_LB_COEF * aux["moe_lb"] / cfg.n_layers
        total = total + MOE_Z_COEF * aux["moe_z"] / cfg.n_layers
    return total, dict(ce=loss, **aux)


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    comp_cfg: CompressionConfig | None = None,
                    microbatch: int = 1, seq_chunk: int = 512,
                    constrain=None):
    """Returns step(params, opt_state, err_state, batch) ->
    (params, opt_state, err_state, metrics)."""
    comp_cfg = comp_cfg or CompressionConfig()

    def grads_of(params, batch):
        (l, metrics), g = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, seq_chunk, constrain),
            has_aux=True)(params)
        return l, metrics, g

    def step(params, opt_state, err_state, batch):
        if microbatch > 1:
            # gradient accumulation over microbatches (sequential scan keeps
            # peak activation memory at 1/microbatch)
            def split(x):
                b = x.shape[0] if x.ndim >= 1 else None
                if x.ndim == 3 and x.shape[0] == 3:      # (3,B,S) positions
                    return jnp.moveaxis(
                        x.reshape(3, microbatch, -1, *x.shape[2:]), 1, 0)
                return x.reshape(microbatch, -1, *x.shape[1:])
            mb = {k: split(v) for k, v in batch.items()}

            def acc_body(carry, mbatch):
                g_acc, l_acc = carry
                l, metrics, g = grads_of(params, mbatch)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + l), metrics
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            (g, lsum), metrics = jax.lax.scan(acc_body, (g0, 0.0), mb)
            g = jax.tree.map(lambda x: x / microbatch, g)
            loss = lsum / microbatch
            metrics = jax.tree.map(lambda x: x[-1], metrics)
        else:
            loss, metrics, g = grads_of(params, batch)

        g, err_state = compress_grads(comp_cfg, g, err_state)
        params, opt_state, opt_m = adamw.apply_updates(
            opt_cfg, params, g, opt_state)
        metrics = dict(loss=loss, **metrics, **opt_m)
        return params, opt_state, err_state, metrics

    return step
