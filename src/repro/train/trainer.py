"""Trainer: the fault-tolerant training loop.

Large-scale operational features (designed for 1000+ nodes, exercised here
on the host mesh):

  checkpoint/restart   — atomic async checkpoints every ``ckpt_every`` steps;
                         on (re)start the trainer resumes from the newest
                         committed step, replaying the deterministic data
                         stream (batch = f(seed, step), no iterator state).
  preemption safety    — SIGTERM triggers a final blocking checkpoint
                         before exit (the TPU-pod eviction contract).
  elastic scaling      — checkpoints are topology-free (see checkpointer);
                         restore re-shards onto whatever mesh is up.
  straggler mitigation — per-step wall-time EWMA; steps slower than
                         ``straggler_factor``× the EWMA are logged and
                         counted (on real multi-host deployments this signal
                         feeds the job scheduler to replace slow hosts; here
                         it drives the metric + hook).
  loss-spike guard     — optional rollback-on-NaN: restore last checkpoint
                         and skip the bad data window.
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, init_error_state
from .train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_ckpts: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    rollback_on_nan: bool = True
    microbatch: int = 1
    seq_chunk: int = 512


class Trainer:
    def __init__(self, cfg, arch_cfg, params, dataset, opt_cfg=None,
                 comp_cfg=None, step_fn=None, constrain=None):
        self.cfg = cfg
        self.arch = arch_cfg
        self.params = params
        self.dataset = dataset
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=cfg.total_steps)
        self.comp_cfg = comp_cfg or CompressionConfig()
        self.opt_state = adamw.init_state(params)
        self.err_state = init_error_state(params, self.comp_cfg)
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self.step = 0
        self.metrics_log: list = []
        self.n_stragglers = 0
        self._ewma = None
        self._stop = False
        fn = step_fn or make_train_step(
            self.arch, self.opt_cfg, self.comp_cfg,
            microbatch=cfg.microbatch, seq_chunk=cfg.seq_chunk,
            constrain=constrain)
        self._jit_step = jax.jit(fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------- lifecycle
    def install_signal_handler(self):
        def _handler(signum, frame):
            self._stop = True
        signal.signal(signal.SIGTERM, _handler)

    def maybe_resume(self):
        latest = self.ckpt.latest_step()
        if latest is not None:
            state = dict(params=self.params, opt=self.opt_state)
            state = self.ckpt.restore(latest, state)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = latest
            return latest
        return None

    def save(self, blocking=False):
        self.ckpt.save(self.step, dict(params=self.params, opt=self.opt_state),
                       blocking=blocking)

    # ------------------------------------------------------------------ run
    def run(self, n_steps=None):
        target = self.step + n_steps if n_steps else self.cfg.total_steps
        while self.step < target and not self._stop:
            batch_np = self.dataset.batch(self.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch_np.items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, self.err_state, metrics = \
                self._jit_step(self.params, self.opt_state, self.err_state,
                               batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler detection (EWMA over steady-state step times)
            if self.step > 1:
                if self._ewma is None:
                    self._ewma = dt
                elif dt > self.cfg.straggler_factor * self._ewma:
                    self.n_stragglers += 1
                else:
                    self._ewma = 0.9 * self._ewma + 0.1 * dt
            # NaN rollback
            if self.cfg.rollback_on_nan and not np.isfinite(loss):
                latest = self.ckpt.latest_step()
                if latest is not None:
                    self.maybe_resume()
                    self.step += 1          # skip the offending window
                    continue
            self.step += 1
            self.metrics_log.append(
                dict(step=self.step, loss=loss, dt=dt,
                     grad_norm=float(metrics.get("grad_norm", 0.0))))
            if self.step % self.cfg.log_every == 0:
                print(f"step {self.step:6d}  loss {loss:.4f}  "
                      f"{dt*1000:.0f} ms", flush=True)
            if self.step % self.cfg.ckpt_every == 0:
                self.save()
        if self._stop:                       # preemption: final checkpoint
            self.save(blocking=True)
        self.ckpt.wait()
        return self.metrics_log
