"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import QWEN3_MOE_30B_A3B as CONFIG

CONFIG = CONFIG
