"""Registry of the 10 assigned architectures. ``get(name)``/``--arch <id>``."""
from __future__ import annotations

from .base import ArchConfig, MoECfg, MambaCfg

# --------------------------------------------------------------------------
# LM-family transformers (exact configs from the assignment / public lit)
# --------------------------------------------------------------------------
PHI3_MEDIUM_14B = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352, act="swiglu", rope_type="std",
)  # [arXiv:2404.14219] RoPE SwiGLU GQA

INTERNLM2_20B = ArchConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92544, act="swiglu", rope_type="std",
)  # [arXiv:2403.17297]

GEMMA_7B = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000, act="geglu", rope_type="std",
    tie_embeddings=True,
)  # [arXiv:2403.08295] GeGLU, head_dim=256

COMMAND_R_PLUS_104B = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=33792, vocab=256000, act="swiglu", rope_type="std",
    fsdp=True,
)  # [hf:CohereForAI] GQA, no-bias

GROK_1_314B = ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab=131072, act="geglu", rope_type="std",
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32768, every=1,
               shard="ffn"),   # 8 experts < 16-way model axis → shard d_ff
    fsdp=True,
)  # [hf:xai-org/grok-1] 8e top-2

QWEN3_MOE_30B_A3B = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936, act="swiglu", rope_type="std",
    moe=MoECfg(n_experts=128, top_k=8, d_ff_expert=768, every=1,
               shard="expert"),
)  # [hf:Qwen/Qwen3-30B-A3B] 128e top-8

JAMBA_1_5_LARGE_398B = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, act="swiglu", rope_type=None,  # Jamba: no RoPE
    attn_every=8, mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    moe=MoECfg(n_experts=16, top_k=2, d_ff_expert=24576, every=2,
               shard="expert"),
    sub_quadratic=True, fsdp=True,
)  # [arXiv:2403.19887] Mamba+attn 1:7, MoE every 2

MUSICGEN_MEDIUM = ArchConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, act="gelu", rope_type=None,
    embeddings_input=True,   # EnCodec frame embeddings (frontend stub)
)  # [arXiv:2306.05284] decoder-only over EnCodec tokens

RWKV6_1_6B = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab=65536, act="rwkv", rope_type=None,
    rwkv6=True, rwkv_head_size=64, sub_quadratic=True,
)  # [arXiv:2404.05892] Finch, data-dependent decay

QWEN2_VL_7B = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab=152064, act="swiglu", rope_type="mrope",
    mrope_sections=(16, 24, 24), qkv_bias=True,
    embeddings_input=True,   # vision patch embeddings (frontend stub)
)  # [arXiv:2409.12191] M-RoPE, dynamic resolution


ARCHS = {c.name: c for c in [
    PHI3_MEDIUM_14B, INTERNLM2_20B, GEMMA_7B, COMMAND_R_PLUS_104B,
    GROK_1_314B, QWEN3_MOE_30B_A3B, JAMBA_1_5_LARGE_398B, MUSICGEN_MEDIUM,
    RWKV6_1_6B, QWEN2_VL_7B,
]}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
