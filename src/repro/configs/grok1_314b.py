"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import GROK_1_314B as CONFIG

CONFIG = CONFIG
