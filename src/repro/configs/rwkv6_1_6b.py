"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import RWKV6_1_6B as CONFIG

CONFIG = CONFIG
