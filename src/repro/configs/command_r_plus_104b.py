"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import COMMAND_R_PLUS_104B as CONFIG

CONFIG = CONFIG
