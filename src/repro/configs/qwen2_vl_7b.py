"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import QWEN2_VL_7B as CONFIG

CONFIG = CONFIG
