"""Architecture config system (assigned public-pool architectures).

One ArchConfig fully determines parameter shapes, layer pattern, sharding
policy and input specs.  ``reduced()`` produces the CPU-smoke-test variant
(same family/topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every: int = 1               # MoE on layers where (idx % every == every-1)
    capacity_factor: float = 1.25
    shard: str = "expert"        # "expert" (E over model axis) | "ffn"


@dataclasses.dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 → ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | audio | ssm | vlm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free
    n_kv_heads: int
    d_ff: int                    # dense FFN width (0 if all-MoE)
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    act: str = "swiglu"          # swiglu | geglu | gelu (plain MLP)
    rope_type: Optional[str] = "std"   # std | mrope | None
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)
    qkv_bias: bool = False
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    # hybrid (Jamba-style): one attention layer per `attn_every` layers,
    # the rest Mamba. attn_every == 0 → all-attention.
    attn_every: int = 0
    mamba: Optional[MambaCfg] = None
    rwkv6: bool = False          # attention-free RWKV6 time/channel mix
    rwkv_head_size: int = 64
    embeddings_input: bool = False   # modality frontend stub feeds embeddings
    sub_quadratic: bool = False      # long_500k applicability
    # distribution policy
    fsdp: bool = False           # additionally shard params over 'data'
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def period(self) -> int:
        """Layer-pattern period for scan-over-blocks."""
        return self.attn_every if self.attn_every > 0 else 1

    @property
    def n_periods(self) -> int:
        assert self.n_layers % self.period == 0
        return self.n_layers // self.period

    def layer_kinds(self) -> list:
        """Kinds of the `period` sub-layers: 'attn' | 'mamba' | 'rwkv'."""
        if self.rwkv6:
            return ["rwkv"] * self.period
        if self.attn_every > 0:
            # Jamba places the attention layer mid-block (index 4 of 8 in
            # Jamba-1.5); position 0 keeps dependency simple and is
            # performance-equivalent for dry-run purposes.
            return ["attn"] + ["mamba"] * (self.period - 1)
        return ["attn"] * self.period

    def ffn_kinds(self) -> list:
        """Per sub-layer position: 'moe' | 'dense'."""
        if self.moe is None:
            return ["dense"] * self.period
        return ["moe" if (i % self.moe.every == self.moe.every - 1) else "dense"
                for i in range(self.period)]

    def param_count(self) -> float:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for kind, fkind in zip(self.layer_kinds(), self.ffn_kinds()):
            if kind == "attn":
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            elif kind == "mamba":
                m = self.mamba or MambaCfg()
                di = m.expand * d
                dtr = m.dt_rank or -(-d // 16)
                total += d * 2 * di + di * m.d_conv + di * (dtr + 2 * m.d_state) \
                    + dtr * di + di * m.d_state + di + di * d
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o (+ small loras elided)
            if fkind == "moe":
                total += self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            elif self.d_ff:
                n_mat = 2 if self.act == "gelu" else 3
                total += n_mat * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_period = total - emb          # blocks repeat n_periods times
        return emb + per_period * self.n_periods

    def active_param_count(self) -> float:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        moe_p = self.moe.n_experts * 3 * self.d_model * self.moe.d_ff_expert
        act_p = (self.moe.top_k + 0) * 3 * self.d_model * self.moe.d_ff_expert
        n_moe_layers = sum(1 for f in self.ffn_kinds() if f == "moe") \
            * self.n_periods
        return full - n_moe_layers * (moe_p - act_p)

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=self.period * 2 if self.attn_every else 2,
            d_model=64,
            n_heads=0 if self.rwkv6 else 4,
            n_kv_heads=0 if self.rwkv6 else 2,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
        )
        if self.moe is not None:
            kw["moe"] = MoECfg(n_experts=4, top_k=2, d_ff_expert=32,
                               every=self.moe.every, shard=self.moe.shard)
        else:
            kw["moe"] = None
        if self.mamba is not None:
            kw["mamba"] = MambaCfg(d_state=4, d_conv=4, expand=2, dt_rank=8)
        else:
            kw["mamba"] = None
        if self.rwkv6:
            kw["rwkv_head_size"] = 16
        kw["mrope_sections"] = (2, 3, 3)
        return ArchConfig(**kw)
