"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import MUSICGEN_MEDIUM as CONFIG

CONFIG = CONFIG
