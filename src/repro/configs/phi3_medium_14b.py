"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import PHI3_MEDIUM_14B as CONFIG

CONFIG = CONFIG
