"""Input-shape suites (assigned) + ShapeDtypeStruct input specs per cell.

  train_4k      seq_len=4096    global_batch=256   → train_step
  prefill_32k   seq_len=32768   global_batch=32    → prefill_step
  decode_32k    seq_len=32768   global_batch=128   → decode_step (1 new token
                                                     against a 32k KV cache)
  long_500k     seq_len=524288  global_batch=1     → decode_step; only for
                sub-quadratic archs (ssm/hybrid) — skip noted in DESIGN.md

``[audio]``/``[vlm]`` archs take precomputed frame/patch embeddings
(modality frontend is a stub per the assignment).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def cell_applicable(arch: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, ("skipped: pure full-attention arch; long_500k needs "
                       "sub-quadratic attention (DESIGN.md §Arch-applicability)")
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeCfg, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = dict(
            tokens=sds((b, s), jnp.int32),
            labels=sds((b, s), jnp.int32),
        )
        if arch.embeddings_input:
            specs["embeds"] = sds((b, s, arch.d_model), dtype)
        if arch.rope_type == "mrope":
            specs["positions"] = sds((3, b, s), jnp.int32)
        return specs
    if shape.kind == "prefill":
        specs = dict(tokens=sds((b, s), jnp.int32))
        if arch.embeddings_input:
            specs["embeds"] = sds((b, s, arch.d_model), dtype)
        if arch.rope_type == "mrope":
            specs["positions"] = sds((3, b, s), jnp.int32)
        return specs
    # decode: one new token against a cache of length seq_len
    from repro.models.transformer import cache_specs
    specs = dict(
        tokens=sds((b, 1), jnp.int32),
        pos=sds((), jnp.int32),
        cache=cache_specs(arch, b, s, dtype=dtype),
    )
    if arch.embeddings_input:
        specs["embeds"] = sds((b, 1, arch.d_model), dtype)
    if arch.rope_type == "mrope":
        specs["positions"] = sds((3, b, 1), jnp.int32)
    return specs
