"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import JAMBA_1_5_LARGE_398B as CONFIG

CONFIG = CONFIG
