"""Assigned architecture config (see registry.py for the literature source)."""
from .registry import GEMMA_7B as CONFIG

CONFIG = CONFIG
