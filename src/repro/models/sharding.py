"""Sharding policy: param/cache/input PartitionSpecs per mesh.

Scheme (Megatron-style TP on 'model', DP over 'data' (+'pod'), optional
FSDP over 'data' for ≥100B archs):

  embeddings / lm_head (V, d)      → vocab on 'model'  (chunked CE keeps the
                                     sharded-logits form; no full-vocab gather)
  attn  wq/wk/wv (d, H·hd)         → heads on 'model' (GSPMD pads non-divisible
        wo (H·hd, d)                 head counts; kv-head padding is the
                                     documented memory cost of TP>kv)
  mlp   up/gate (d, f) ↔ down      → f on 'model'
  moe   experts (E, d, f)          → E on 'model' (shard="expert") or f on
                                     'model' (shard="ffn", e.g. grok's E=8<16)
  mamba d_inner dims               → 'model'
  rwkv  head dims                  → 'model'
  norms, routers, mixes            → replicated
  FSDP  (cfg.fsdp)                 → additionally shard d_model dim on 'data'

Caches: batch on data axes when divisible, else *sequence* dim on 'data'
(sequence-parallel KV for long_500k's batch=1), kv-heads/state on 'model'.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def data_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# --------------------------------------------------------------------------
# trace-time mesh context: lets layer internals (MoE dispatch buffers, SSM
# intermediates) pin shardings without threading the mesh through every call.
# Set by dryrun/train launchers before tracing; no-op otherwise.
# --------------------------------------------------------------------------
_CTX = {"mesh": None}


def set_mesh_context(mesh):
    _CTX["mesh"] = mesh


def ctx_groups() -> int:
    """Number of data-parallel groups in the mesh context (1 without one).
    MoE dispatch keeps capacity/ranking local to each group."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return 1
    g = 1
    for a in data_axes(mesh):
        g *= mesh.shape[a]
    return g


def ctx_constrain(x, *dims):
    """Constrain x to PartitionSpec(*dims) where 'dp' expands to the data
    axes tuple. No-op without a mesh context."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding
    daxes = data_axes(mesh)
    spec = P(*[daxes if d == "dp" else d for d in dims])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(mesh) -> P:
    return P(data_axes(mesh),)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_specs(cfg: ArchConfig, params_shapes) -> dict:
    """PartitionSpec pytree matching the params tree (works on either real
    params or a ShapeDtypeStruct tree)."""
    moe_shard = cfg.moe.shard if cfg.moe else "expert"

    def rule(path, leaf):
        p = _path_str(path)
        nd = len(leaf.shape)
        lead = (None,) if "blocks" in p else ()   # stacked period axis

        def spec(*tail):
            full = lead + tail
            assert len(full) == nd, (p, leaf.shape, full)
            return P(*full)

        name = p.split("/")[-1]
        if name in ("embed", "lm_head"):
            return P("model", None)
        if nd - len(lead) == 1:                    # biases/norms/mixes
            if name in ("bq", "bk", "bv", "conv_b", "dt_bias", "d_skip"):
                return spec("model")
            return spec(None)
        dsh = "data" if cfg.fsdp else None
        if name in ("wq", "wk", "wv"):
            return spec(dsh, "model")
        if name == "wo":
            return spec("model", dsh)
        if name in ("w_gate", "w_up"):
            if nd - len(lead) == 3:                # MoE experts (E, d, f)
                return spec("model", dsh, None) if moe_shard == "expert" \
                    else spec(None, dsh, "model")
            return spec(dsh, "model")
        if name == "w_down":
            if nd - len(lead) == 3:                # (E, f, d)
                return spec("model", None, dsh) if moe_shard == "expert" \
                    else spec(None, "model", dsh)
            return spec("model", dsh)
        if name == "router":
            return spec(None, None)
        # mamba
        if name == "in_proj":
            return spec(dsh, "model")
        if name == "conv_w":
            return spec(None, "model")
        if name == "x_proj":
            return spec("model", None)
        if name == "dt_proj":
            return spec(None, "model")
        if name == "a_log":
            return spec("model", None)
        if name == "out_proj":
            return spec("model", dsh)
        # rwkv (wk/wv hit the attention rule above — same layout intent)
        if name in ("wr", "wg"):
            return spec(dsh, "model")
        if name == "w1":
            return spec(None, None)
        if name == "w2":
            return spec(None, "model")
        if name == "u":
            return spec("model", None)
        if name == "ck":
            return spec(dsh, "model")
        if name == "cv":
            return spec("model", dsh)
        if name == "cr":
            return spec(dsh, None)
        # rwkv reuses wk/wv names — handled above (2D: d→model out) ✓
        return spec(*([None] * (nd - len(lead))))

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def cache_spec_tree(cfg: ArchConfig, cache_shapes, mesh) -> list:
    """Specs for the decode cache (leaves lead with n_periods)."""
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]

    msize = mesh.shape.get("model", 1)

    def rule_fix(path, leaf):
        shape = leaf.shape
        b = shape[1]
        batch_ok = b % dsize == 0
        bspec = daxes if batch_ok else None
        nd = len(shape)
        if nd == 5 and shape[3] == cfg.n_kv_heads:      # attn kv cache
            if cfg.n_kv_heads % msize == 0:
                sspec = None if batch_ok else "data"
                return P(None, bspec, sspec, "model", None)
            # kv heads don't divide the model axis (explicit *argument*
            # shardings must divide): sequence-parallel KV cache instead
            if shape[2] % msize == 0:
                return P(None, bspec, "model", None, None)
            return P(None, bspec, None, None, None)
        if nd == 5:                                     # rwkv state (np,B,nh,hs,hs)
            return P(None, bspec, "model", None, None)
        if nd == 4 and cfg.mamba and shape[2] != (cfg.mamba.d_conv - 1):
            return P(None, bspec, "model", None)        # mamba h (np,B,di,n)
        if nd == 4:                                     # mamba conv (np,B,kw-1,di)
            return P(None, bspec, None, "model")
        if nd == 3:                                     # rwkv xprev (np,B,d)
            return P(None, bspec, None)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        rule_fix, cache_shapes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def activation_constrainer(mesh):
    """Residual-stream constraint for Megatron-SP: (B, S, d) lives batch-
    sharded over data axes and sequence-sharded over 'model' at block
    boundaries, so per-layer saved activations cost 1/(dp·tp) each."""
    from jax.sharding import NamedSharding
    daxes = data_axes(mesh)
    sh = NamedSharding(mesh, P(daxes, "model", None))

    def constrain(x):
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(x, sh)
        return x

    return constrain


def zero_specs(pspecs, pshapes, mesh):
    """ZeRO-style optimizer-state sharding: take the param spec and shard
    the first still-replicated, divisible dimension over 'data'."""
    dsize = mesh.shape.get("data", 1)

    def rule(spec, shape):
        dims = list(spec) + [None] * (len(shape.shape) - len(spec))
        if "data" in [d for dim in dims for d in
                      ((dim,) if not isinstance(dim, tuple) else dim)]:
            return spec
        for i, (d, n) in enumerate(zip(dims, shape.shape)):
            if d is None and n % dsize == 0 and n >= dsize:
                dims[i] = "data"
                return P(*dims)
        return spec

    return jax.tree.map(rule, pspecs, pshapes,
                        is_leaf=lambda x: isinstance(x, P))


def input_spec_tree(cfg: ArchConfig, specs: dict, mesh) -> dict:
    daxes = data_axes(mesh)
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_spec_tree(cfg, v, mesh)
        elif k == "pos":
            out[k] = P()
        elif k == "positions":                 # (3, B, S)
            b = v.shape[1]
            out[k] = P(None, daxes if b % dsize == 0 else None, None)
        elif k == "embeds":
            b = v.shape[0]
            out[k] = P(daxes if b % dsize == 0 else None, None, None)
        else:                                  # tokens/labels (B, S)
            b = v.shape[0]
            out[k] = P(daxes if b % dsize == 0 else None, None)
    return out
