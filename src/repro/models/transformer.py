"""Unified decoder model over the arch-config family.

Layer pattern: the config defines a *period* of sub-layers (e.g. Jamba:
1 attention + 7 Mamba per period, MoE every 2nd position); the model scans
over ``n_periods`` with per-position parameter stacks.  This keeps HLO size
and compile time independent of depth (64–72-layer archs compile in seconds
on 512 fake devices) — the roofline parser multiplies while-body costs by
trip count.

Params tree:
  embed (V, d) [+ lm_head unless tied]  · final_norm
  blocks: list over period positions, each a dict of stacked (n_periods, ...)
  sub-layer params: {kind, ln1, attn/mamba/rwkv, ln2, mlp/moe}
"""
from __future__ import annotations

import functools
from typing import Optional  # noqa: F401

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MambaCfg
from . import layers as L

F32 = jnp.float32


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.period + 2)
    params = dict(
        embed=(jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), F32)
               * 0.02).astype(dtype),
        final_norm=L.init_rms(cfg.d_model, dtype),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.vocab, cfg.d_model), F32) * 0.02).astype(dtype)

    kinds = cfg.layer_kinds()
    fkinds = cfg.ffn_kinds()
    blocks = []
    for pos in range(cfg.period):
        def init_one(k):
            sub = {"ln1": L.init_rms(cfg.d_model, dtype)}
            kk = jax.random.split(k, 3)
            if kinds[pos] == "attn":
                sub["attn"] = L.init_attention(cfg, kk[0], dtype)
            elif kinds[pos] == "mamba":
                sub["mamba"] = L.init_mamba(cfg, kk[0], dtype)
            else:
                sub["rwkv"] = L.init_rwkv(cfg, kk[0], dtype)
            if kinds[pos] != "rwkv":     # rwkv carries its own channel mix
                sub["ln2"] = L.init_rms(cfg.d_model, dtype)
                if fkinds[pos] == "moe":
                    sub["ffn"] = L.init_moe(cfg, kk[1], dtype)
                elif cfg.d_ff:
                    sub["ffn"] = L.init_mlp(cfg, kk[1], dtype)
            return sub
        pk = jax.random.split(keys[2 + pos], cfg.n_periods)
        blocks.append(jax.vmap(init_one)(pk))
    params["blocks"] = blocks
    return params


# --------------------------------------------------------------------------
# sub-layer application (sequence / step)
# --------------------------------------------------------------------------
def _sublayer_seq(cfg, kind, fkind, sub, x, positions, collect_cache=False):
    aux = {}
    cache = None
    h = L.rms_norm(x, sub["ln1"], cfg.norm_eps)
    if kind == "attn":
        o, kv = L.attention_seq(cfg, sub["attn"], h, positions)
        if collect_cache:
            cache = kv
        x = x + o
    elif kind == "mamba":
        if collect_cache:
            o, cache = L.mamba_seq(cfg, sub["mamba"], h, return_state=True)
        else:
            o = L.mamba_seq(cfg, sub["mamba"], h)
        x = x + o
    else:
        o, st = L.rwkv_time_mix_seq(cfg, sub["rwkv"], h,
                                    return_state=collect_cache)
        x = x + o
        h2 = L.rms_norm(x, sub["rwkv"]["ln_cm"], cfg.norm_eps)
        x = x + L.rwkv_channel_mix(cfg, sub["rwkv"], h2)
        if collect_cache:
            cache = (st[0], st[1], h2[:, -1])
        return x, aux, cache
    if "ffn" in sub:
        h = L.rms_norm(x, sub["ln2"], cfg.norm_eps)
        if fkind == "moe":
            o, moe_aux = L.moe(cfg, sub["ffn"], h)
            aux.update(moe_aux)
        else:
            o = L.mlp(cfg, sub["ffn"], h)
        x = x + o
    return x, aux, cache


def _sublayer_step(cfg, kind, fkind, sub, x, positions, state, pos):
    h = L.rms_norm(x, sub["ln1"], cfg.norm_eps)
    if kind == "attn":
        o, state = L.attention_step(cfg, sub["attn"], h, positions, state, pos)
        x = x + o
    elif kind == "mamba":
        o, state = L.mamba_step(cfg, sub["mamba"], h, state)
        x = x + o
    else:
        o, st_t = L.rwkv_time_mix_step(cfg, sub["rwkv"], h, state[:2])
        x = x + o
        h2 = L.rms_norm(x, sub["rwkv"]["ln_cm"], cfg.norm_eps)
        xprev_cm = state[2]
        x = x + L.rwkv_channel_mix(cfg, sub["rwkv"], h2[:, 0],
                                   x_prev=xprev_cm)[:, None, :]
        state = (st_t[0], st_t[1], h2[:, 0])
        return x, state
    if "ffn" in sub:
        h = L.rms_norm(x, sub["ln2"], cfg.norm_eps)
        if fkind == "moe":
            o, _ = L.moe(cfg, sub["ffn"], h)
        else:
            o = L.mlp(cfg, sub["ffn"], h)
        x = x + o
    return x, state


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------
def forward(cfg: ArchConfig, params, tokens=None, embeds=None, positions=None,
            collect_cache=False, remat: Optional[bool] = None,
            constrain=None):
    """Returns (hidden (B,S,d), aux, caches|None). Logits via lm_logits().

    constrain: optional fn(x) applying a sharding constraint to the residual
    stream at period boundaries (Megatron-SP: saved activations live
    sequence-sharded over the 'model' axis; GSPMD inserts the all-gather /
    reduce-scatter pair around each block)."""
    remat = cfg.remat if remat is None else remat
    constrain = constrain or (lambda x: x)
    if embeds is not None:
        x = embeds
        if tokens is not None:   # mixed stub: tokens embedded + added
            x = x + params["embed"][tokens].astype(x.dtype)
    else:
        x = params["embed"][tokens]
    b, s = x.shape[:2]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0) \
            if cfg.rope_type != "mrope" else \
            jnp.arange(s, dtype=jnp.int32)[None, None, :].repeat(b, 1).repeat(3, 0)
    kinds = cfg.layer_kinds()
    fkinds = cfg.ffn_kinds()

    def period_body(x, block_slices):
        auxes = {}
        caches = []
        for pos in range(cfg.period):
            # every checkpointed sub-layer's saved input lives seq-sharded
            # over 'model' (Megatron-SP): 1/(dp·tp) memory per residual
            x = constrain(x)
            sub = block_slices[pos]
            fn = lambda xx, ss, _pos=pos: _sublayer_seq(
                cfg, kinds[_pos], fkinds[_pos], ss, xx, positions,
                collect_cache)
            if remat:
                fn = jax.checkpoint(fn,
                                    policy=jax.checkpoint_policies.nothing_saveable)
            x, aux, cache = fn(x, sub)
            for k2, v2 in aux.items():
                auxes[k2] = auxes.get(k2, 0.0) + v2
            caches.append(cache)
        x = constrain(x)
        return x, (auxes, caches)

    def scan_body(x, blk):
        x, (aux, caches) = period_body(x, blk)
        return x, (aux, caches if collect_cache else None)

    x, (auxes, caches) = jax.lax.scan(scan_body, x, params["blocks"])
    aux = {k: v.sum() for k, v in auxes.items()}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    # caches: list over period positions, leaves stacked (n_periods, B, S, ...)
    return x, aux, caches


def lm_logits(cfg: ArchConfig, params, hidden):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("bsd,vd->bsv", hidden, head)


def ce_loss_chunked(cfg: ArchConfig, params, hidden, labels, seq_chunk=512):
    """Cross-entropy without materializing (B,S,V) logits: chunk the
    sequence; per chunk compute logits (bf16 matmul, f32 reductions)."""
    head = (params["embed"] if cfg.tie_embeddings else params["lm_head"])
    b, s, d = hidden.shape
    nch = -(-s // seq_chunk)
    sp = nch * seq_chunk
    if sp != s:
        hidden = jnp.pad(hidden, ((0, 0), (0, sp - s), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, sp - s)), constant_values=-1)
    hc = jnp.moveaxis(hidden.reshape(b, nch, seq_chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nch, seq_chunk), 1, 0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_ce(hidden_c, labels_c):
        # rematted: the (B, chunk, V) logits are recomputed in backward
        # instead of being saved per chunk (vocab 256k would cost GiBs).
        logits = jnp.einsum("bsd,vd->bsv", hidden_c, head).astype(F32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(labels_c, 0)[..., None], axis=-1)[..., 0]
        valid = (labels_c >= 0).astype(F32)
        return ((lse - tgt) * valid).sum(), valid.sum()

    def chunk_loss(carry, inp):
        hidden_c, labels_c = inp
        loss, cnt = chunk_ce(hidden_c, labels_c)
        return (carry[0] + loss, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(chunk_loss, (0.0, 0.0), (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def cache_specs(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the decode cache: list over the `period` sub-layer
    positions; leaves stacked over periods (n_periods, ...) — the same layout
    ``forward(collect_cache=True)`` produces and ``decode_step`` scans."""
    sds = jax.ShapeDtypeStruct
    hd = cfg.resolved_head_dim
    m = cfg.mamba or MambaCfg()
    di = m.expand * cfg.d_model
    nh = cfg.d_model // cfg.rwkv_head_size if cfg.rwkv6 else 0
    np_ = cfg.n_periods
    out = []
    for kind in cfg.layer_kinds():
        if kind == "attn":
            out.append((sds((np_, batch, s_max, cfg.n_kv_heads, hd), dtype),
                        sds((np_, batch, s_max, cfg.n_kv_heads, hd), dtype)))
        elif kind == "mamba":
            out.append((sds((np_, batch, m.d_conv - 1, di), dtype),
                        sds((np_, batch, di, m.d_state), F32)))
        else:
            out.append((sds((np_, batch, cfg.d_model), dtype),
                        sds((np_, batch, nh, cfg.rwkv_head_size,
                             cfg.rwkv_head_size), F32),
                        sds((np_, batch, cfg.d_model), dtype)))
    return out


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, s_max, dtype),
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def decode_step(cfg: ArchConfig, params, tokens, cache, pos, embeds=None,
                positions=None):
    """One token for every sequence in the batch. Returns (logits, cache).
    Scans over periods (cache leaves carry a leading n_periods axis)."""
    if embeds is not None:
        x = embeds
        if tokens is not None:
            x = x + params["embed"][tokens].astype(x.dtype)
    else:
        x = params["embed"][tokens]
    b = x.shape[0]
    if positions is None:
        pp = jnp.full((b, 1), pos, jnp.int32)
        positions = pp if cfg.rope_type != "mrope" else \
            jnp.broadcast_to(pp[None], (3, b, 1))
    kinds = cfg.layer_kinds()
    fkinds = cfg.ffn_kinds()

    def scan_body(x, per_slice):
        blk, cache_row = per_slice
        new_row = []
        for posn in range(cfg.period):
            x, st = _sublayer_step(cfg, kinds[posn], fkinds[posn], blk[posn],
                                   x, positions, cache_row[posn], pos)
            new_row.append(st)
        return x, new_row

    x, new_cache = jax.lax.scan(scan_body, x, (params["blocks"], cache))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_logits(cfg, params, x), new_cache
