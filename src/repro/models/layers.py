"""Model-zoo layers: pure-jnp, param-dict based (no flax).

Every layer comes in two execution forms:
  - sequence form  (train/prefill): full (B, S, ...) tensors; attention is
    chunked online-softmax (flash-style in pure XLA; the Pallas kernel in
    repro.kernels.flashattn is the TPU-optimized drop-in, flag-gated);
  - step form (decode): one token, carried cache/state.

Conventions: params are dicts of jnp arrays; an extra leading axis stacks
layers for scan-over-layers (added by transformer.py, not here).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MambaCfg

F32 = jnp.float32

# perf-iteration knobs (EXPERIMENTS.md §Perf): VMEM-ish working-set tiles
# for the pure-XLA paths. Env-tunable so dry-run sweeps can measure them.
import os as _os
ATTN_CHUNK_K = int(_os.environ.get("REPRO_ATTN_CHUNK", "1024"))
MAMBA_CHUNK = int(_os.environ.get("REPRO_MAMBA_CHUNK", "128"))


# --------------------------------------------------------------------------
# basics
# --------------------------------------------------------------------------
def rms_norm(x, w, eps=1e-5):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_rms(d, dtype):
    return jnp.ones((d,), dtype)


def _dense(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale or 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# RoPE / M-RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    ang = positions.astype(F32)[..., None] * freqs      # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def apply_mrope(x, positions, theta, sections):
    """M-RoPE (Qwen2-VL): positions (3, B, S) = (t, h, w) ids; the D/2
    frequency slots are split into `sections` groups, each rotated by its
    own position stream."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # (D/2,)
    sec = jnp.concatenate([jnp.full((s,), i, jnp.int32)
                           for i, s in enumerate(sections)])
    # pick per-slot position stream: (B, S, D/2)
    pos = jnp.take(positions, sec, axis=0)              # (D/2 picks of (B,S))
    pos = jnp.moveaxis(pos, 0, -1).astype(F32)          # (B, S, D/2)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.stack([y1, y2], axis=-1).reshape(x.shape).astype(x.dtype)


def _rope(cfg: ArchConfig, x, positions):
    if cfg.rope_type is None:
        return x
    if cfg.rope_type == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


# --------------------------------------------------------------------------
# attention (GQA, chunked online-softmax)
# --------------------------------------------------------------------------
def init_attention(cfg: ArchConfig, key, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = dict(
        wq=_dense(ks[0], (d, h * hd), dtype),
        wk=_dense(ks[1], (d, hkv * hd), dtype),
        wv=_dense(ks[2], (d, hkv * hd), dtype),
        wo=_dense(ks[3], (h * hd, d), dtype),
    )
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _chunked_causal_attention(q, k, v, q_offset=0, chunk_k=None):
    chunk_k = chunk_k or ATTN_CHUNK_K
    """Online-softmax causal attention in pure XLA: one scan over KV chunks
    with (m, l, acc) carried for all query positions.

    Memory-critical details (dry-run verified):
      - the KV offset is a *carried dynamic counter*, so causal masks are
        recomputed per step from dynamic scalars — XLA cannot hoist
        full-shape mask stacks out of the loop (a 5+ GiB/device trap);
      - each kv_step is jax.checkpoint'ed: the backward pass recomputes the
        (B,H,T,CK) logits per chunk instead of saving them (the pure-XLA
        analogue of flash-attention's O(T) backward).
    q: (B, T, H, D); k/v: (B, S, Hkv, D); returns (B, T, H, D)."""
    b, t, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)
    chunk_k = min(chunk_k, s)
    nk = -(-s // chunk_k)
    sk = nk * chunk_k
    if sk != s:
        k = jnp.pad(k, ((0, 0), (0, sk - s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk - s), (0, 0), (0, 0)))
    kc = k.reshape(b, nk, chunk_k, hkv, d)
    vc = v.reshape(b, nk, chunk_k, hkv, d)
    qs = (q.astype(F32) * scale).astype(q.dtype)
    rows = q_offset + jax.lax.iota(jnp.int32, t)        # (T,)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def kv_step_inner(carry_mlacc, koff, kblk, vblk):
        m, l, acc = carry_mlacc
        logit = jnp.einsum("bqhd,bkhd->bhqk", qs,
                           jnp.repeat(kblk, g, axis=2),
                           preferred_element_type=F32)
        cols = koff + jax.lax.iota(jnp.int32, chunk_k)  # dynamic offset
        mask = (rows[:, None] >= cols[None, :]) & (cols < s)[None, :]
        logit = jnp.where(mask[None, None], logit, -1e30)
        m_new = jnp.maximum(m, logit.max(axis=-1))
        p = jnp.exp(logit - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vblk.dtype),
            jnp.repeat(vblk, g, axis=2), preferred_element_type=F32)
        return m_new, l_new, acc

    def kv_step(carry, inp):
        (koff, m, l, acc) = carry
        kblk, vblk = inp
        m, l, acc = kv_step_inner((m, l, acc), koff, kblk, vblk)
        return (koff + chunk_k, m, l, acc), None

    m0 = jnp.full((b, h, t), -1e30, F32)
    l0 = jnp.zeros((b, h, t), F32)
    a0 = jnp.zeros((b, h, t, d), F32)
    (_, m, l, acc), _ = jax.lax.scan(
        kv_step, (jnp.int32(0), m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)))
    l = jnp.where(l == 0, 1.0, l)
    out = (acc / l[..., None]).astype(q.dtype)          # (B, H, T, D)
    return jnp.moveaxis(out, 1, 2)                      # (B, T, H, D)


def attention_seq(cfg: ArchConfig, p, x, positions, use_flash_kernel=False):
    """Sequence-form attention. positions: (B,S) or (3,B,S) for mrope."""
    from repro.models.sharding import ctx_constrain
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # NOTE (measured in the dry-run): explicit head constraints here FORCE
    # extra reshards and regress memory (phi3 12.2→17.0 GiB); GSPMD's
    # propagation from the Megatron weight shardings picks better layouts.
    # Kept as a documented refuted hypothesis — see EXPERIMENTS.md §Perf.
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    if use_flash_kernel:
        from repro.kernels.flashattn.kernel import flash_attention
        o = flash_attention(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                            jnp.moveaxis(v, 2, 1), interpret=True)
        o = jnp.moveaxis(o, 1, 2)
    else:
        o = _chunked_causal_attention(q, k, v)
    return o.reshape(b, s, h * hd) @ p["wo"], (k, v)


def attention_step(cfg: ArchConfig, p, x, positions, cache_kv, pos):
    """Decode-form attention: x (B,1,d); cache_kv = (k,v) with shape
    (B, S_max, Hkv, D); pos = current write index (0-based)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _rope(cfg, q.reshape(b, 1, h, hd), positions)
    k = _rope(cfg, k.reshape(b, 1, hkv, hd), positions)
    v = v.reshape(b, 1, hkv, hd)
    ck, cv = cache_kv
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype), pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype), pos, axis=1)
    g = h // hkv
    s_max = ck.shape[1]
    kk = jnp.repeat(ck, g, axis=2)
    vv = jnp.repeat(cv, g, axis=2)
    logit = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), kk.astype(F32))
    logit = logit / math.sqrt(hd)
    valid = jnp.arange(s_max)[None, None, None, :] <= pos
    logit = jnp.where(valid, logit, -1e30)
    w = jax.nn.softmax(logit, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w.astype(vv.dtype), vv)
    return o.reshape(b, 1, h * hd) @ p["wo"], (ck, cv)


# --------------------------------------------------------------------------
# FFN: swiglu / geglu / gelu — and MoE
# --------------------------------------------------------------------------
def init_mlp(cfg: ArchConfig, key, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return dict(w_up=_dense(ks[0], (d, f), dtype),
                    w_down=_dense(ks[1], (f, d), dtype))
    return dict(w_gate=_dense(ks[0], (d, f), dtype),
                w_up=_dense(ks[1], (d, f), dtype),
                w_down=_dense(ks[2], (f, d), dtype))


def mlp(cfg: ArchConfig, p, x):
    if cfg.act == "gelu":
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    act = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
    return (act * u) @ p["w_down"]


def init_moe(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    m = cfg.moe
    e, f = m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)
    return dict(
        router=_dense(ks[0], (d, e), dtype, scale=0.02),
        w_gate=_dense(ks[1], (e, d, f), dtype),
        w_up=_dense(ks[2], (e, d, f), dtype),
        w_down=_dense(ks[3], (e, f, d), dtype),
    )


def moe(cfg: ArchConfig, p, x):
    """Group-local, sort-based, capacity-limited top-k dispatch.

    Tokens are split into G groups aligned with the data-parallel shards
    (G = product of data axes in the mesh context; 1 on a single device).
    Ranking/capacity/scatter are all *within-group*, so dispatch never moves
    tokens across data shards — the only collectives are the expert/tensor
    parallel ones over 'model' (GShard-style per-device capacity semantics).

    Memory: O(T·k) indices + (G, E, C_local, d) buffers, sharded
    (dp, 'model'|None, None, ...) per the config's expert-shard mode.
    Returns (out, aux_losses dict)."""
    from repro.models.sharding import ctx_groups, ctx_constrain
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    grp = ctx_groups()
    if t % grp != 0:
        grp = 1
    tl = t // grp                                       # tokens per group
    xf = x.reshape(t, d)
    logits = (xf @ p["router"]).astype(F32)             # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                 # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- within-group ranking via stable sort on (group, expert) keys ----
    flat_e = ids.reshape(grp, tl * k)                   # (G, tl*k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    iota = jnp.broadcast_to(jnp.arange(tl * k, dtype=jnp.int32), (grp, tl * k))
    first = jnp.full((grp, e), tl * k, jnp.int32).at[
        jnp.arange(grp)[:, None], sorted_e].min(iota)
    pos_sorted = iota - jnp.take_along_axis(first, sorted_e, axis=1)
    pos = jnp.zeros((grp, tl * k), jnp.int32).at[
        jnp.arange(grp)[:, None], order].set(pos_sorted)

    cap = max(int(math.ceil(tl * k / e * m.capacity_factor)), 1)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # (G, tl*k)
    # --- dispatch (group-local scatter) ----------------------------------
    xrep = jnp.repeat(xf.reshape(grp, tl, d), k, axis=1)  # (G, tl*k, d)
    # vmap over groups → XLA sees a *batched* scatter (operand_batching_dims)
    # that GSPMD partitions along dp without collective fallback (measured:
    # explicit 2D-index scatters were collective-permuted at 4.3 GiB/layer).
    buf = jax.vmap(lambda sl, xr, kp: jnp.zeros(
        (e * cap + 1, d), x.dtype).at[sl].add(kp[:, None].astype(x.dtype) * xr)
    )(slot, xrep, keep)
    # stage 1: pin the scatter itself data-local (replicated over 'model') —
    # otherwise GSPMD propagates the expert sharding into the scatter and
    # falls back to full rematerialization (all-gather per layer).
    buf = ctx_constrain(buf, "dp", None, None)
    buf = buf[:, :-1].reshape(grp, e, cap, d)
    # stage 2 (expert mode): explicit reshard = the expert-parallel
    # all-to-all (each token crosses the 'model' axis once, as in GShard).
    espec_in = ("dp", "model" if m.shard == "expert" else None, None, None)
    buf = ctx_constrain(buf, *espec_in)
    # --- expert computation (batched over G, E) ---------------------------
    espec_f = ("dp", "model", None, None) if m.shard == "expert" else \
        ("dp", None, None, "model")
    g_ = ctx_constrain(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"]), *espec_f)
    u_ = ctx_constrain(jnp.einsum("gecd,edf->gecf", buf, p["w_up"]), *espec_f)
    act = jax.nn.silu(g_) if cfg.act == "swiglu" else jax.nn.gelu(g_)
    y = jnp.einsum("gecf,efd->gecd", act * u_, p["w_down"])
    y = ctx_constrain(y, *espec_in)
    # reverse all-to-all back to data-local before the combine gather
    y = ctx_constrain(y, "dp", None, None, None)
    # --- combine (group-local gather) -------------------------------------
    yflat = jnp.concatenate([y.reshape(grp, e * cap, d),
                             jnp.zeros((grp, 1, d), y.dtype)], axis=1)
    back = jax.vmap(lambda yf, sl: yf[sl])(yflat, slot)   # batched gather
    back = back * (keep * gate.reshape(grp, tl * k)
                   ).astype(y.dtype)[..., None]
    out = back.reshape(grp, tl, k, d).sum(axis=2).reshape(b, s, d)
    # --- aux losses (Switch LB + router z-loss) ---------------------------
    me = probs.mean(axis=0)                             # (E,)
    ce = jnp.zeros(e, F32).at[flat_e.reshape(-1)].add(
        keep.reshape(-1).astype(F32)) / max(t * k, 1)
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, dict(moe_lb=lb, moe_z=z)


# --------------------------------------------------------------------------
# Mamba (selective SSM, chunked associative scan)
# --------------------------------------------------------------------------
def init_mamba(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    m = cfg.mamba or MambaCfg()
    di = m.expand * d
    dtr = m.dt_rank or -(-d // 16)
    ks = jax.random.split(key, 7)
    return dict(
        in_proj=_dense(ks[0], (d, 2 * di), dtype),
        conv_w=_dense(ks[1], (m.d_conv, di), dtype, scale=0.5),
        conv_b=jnp.zeros((di,), dtype),
        x_proj=_dense(ks[2], (di, dtr + 2 * m.d_state), dtype),
        dt_proj=_dense(ks[3], (dtr, di), dtype),
        dt_bias=jnp.asarray(
            jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
                ks[4], (di,), F32, jnp.log(1e-3), jnp.log(1e-1))))),
            dtype=F32).astype(dtype),
        a_log=jnp.log(jnp.tile(jnp.arange(1, m.d_state + 1, dtype=F32),
                               (di, 1))).astype(dtype),
        d_skip=jnp.ones((di,), dtype),
        out_proj=_dense(ks[5], (di, d), dtype),
    )


def _ssm_scan_chunk(a, bx, h0):
    """h_t = a_t * h_{t-1} + bx_t along axis 1 (time). a/bx: (B, L, DI, N)."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    a_cum, y = jax.lax.associative_scan(comb, (a, bx), axis=1)
    return y + a_cum * h0[:, None], a_cum


def mamba_seq(cfg: ArchConfig, p, x, chunk=None, return_state=False):
    chunk = chunk or MAMBA_CHUNK
    """Sequence form. x: (B, S, d). Chunked selective scan: sequential carry
    across chunks, parallel (associative scan) within a chunk — bounds the
    (B, L, DI, N) intermediate to one chunk."""
    from repro.models.sharding import ctx_constrain
    m = cfg.mamba or MambaCfg()
    b, s, d = x.shape
    di = m.expand * d
    n = m.d_state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = ctx_constrain(xin, "dp", None, "model")   # d_inner tensor-parallel
    z = ctx_constrain(z, "dp", None, "model")       # gate lives across body
    # causal depthwise conv along time
    kw = p["conv_w"].shape[0]
    xpad = jnp.pad(xin, ((0, 0), (kw - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + s] * p["conv_w"][i] for i in range(kw)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    xc = ctx_constrain(xc, "dp", None, "model")
    proj = xc @ p["x_proj"]
    dtr = p["dt_proj"].shape[0]
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])      # (B,S,DI)
    a = -jnp.exp(p["a_log"].astype(F32))                        # (DI,N)

    nchunks = -(-s // chunk)
    sp = nchunks * chunk
    def padt(v):
        return jnp.pad(v, ((0, 0), (0, sp - s)) + ((0, 0),) * (v.ndim - 2))
    dt_, b_, c_, xc_ = padt(dt), padt(bmat), padt(cmat), padt(xc)
    dt_ = dt_.reshape(b, nchunks, chunk, di)
    b_ = b_.reshape(b, nchunks, chunk, n)
    c_ = c_.reshape(b, nchunks, chunk, n)
    xc_ = xc_.reshape(b, nchunks, chunk, di)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_step_inner(h, dtc, bc, cc, xcc):
        # rematted: backward recomputes the (B,L,DI,N) scan states per chunk
        # instead of saving them (32 chunks × 34 GiB would not fit anywhere)
        abar = jnp.exp(dtc.astype(F32)[..., None] * a)          # (B,L,DI,N)
        bx = (dtc * xcc).astype(F32)[..., None] * bc.astype(F32)[:, :, None, :]
        abar = ctx_constrain(abar, "dp", None, "model", None)
        bx = ctx_constrain(bx, "dp", None, "model", None)
        hs, a_cum = _ssm_scan_chunk(abar, bx, h)
        y = jnp.einsum("blin,bln->bli", hs, cc.astype(F32))
        return hs[:, -1], y

    def chunk_step(h, inp):
        dtc, bc, cc, xcc = inp                  # (B, L, ...)
        h_next, y = chunk_step_inner(h, dtc, bc, cc, xcc)
        return h_next, y

    h0 = jnp.zeros((b, di, n), F32)
    h_fin, ys = jax.lax.scan(chunk_step, h0,
                             (jnp.moveaxis(dt_, 1, 0), jnp.moveaxis(b_, 1, 0),
                              jnp.moveaxis(c_, 1, 0), jnp.moveaxis(xc_, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, sp, di)[:, :s]
    y = (y + xc.astype(F32) * p["d_skip"].astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if return_state:
        # NOTE: h_fin is the state after position sp-1 (padded); with padding
        # dt=0 → abar=1, bx=0 → padded steps are identity. Exactly h after s-1.
        conv_buf = jnp.pad(xin, ((0, 0), (kw - 1, 0), (0, 0)))[:, s:s + kw - 1]
        return out, (conv_buf.astype(x.dtype), h_fin)
    return out


def mamba_step(cfg: ArchConfig, p, x, state):
    """Decode form. x: (B,1,d); state = (conv_buf (B,kw-1,DI), h (B,DI,N))."""
    m = cfg.mamba or MambaCfg()
    b = x.shape[0]
    n = m.d_state
    conv_buf, h = state
    xz = x[:, 0] @ p["in_proj"]
    di = h.shape[1]
    xin, z = jnp.split(xz, 2, axis=-1)
    kw = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_buf, xin[:, None, :]], axis=1)  # (B,kw,DI)
    xc = jnp.einsum("bki,ki->bi", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    conv_buf = window[:, 1:]
    proj = xc @ p["x_proj"]
    dtr = p["dt_proj"].shape[0]
    dt, bvec, cvec = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ p["dt_proj"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(F32))
    abar = jnp.exp(dt.astype(F32)[..., None] * a)               # (B,DI,N)
    bx = (dt * xc).astype(F32)[..., None] * bvec.astype(F32)[:, None, :]
    h = abar * h + bx
    y = jnp.einsum("bin,bn->bi", h, cvec.astype(F32))
    y = (y + xc.astype(F32) * p["d_skip"].astype(F32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None, :], (conv_buf, h)


# --------------------------------------------------------------------------
# RWKV6 (Finch): time-mix with data-dependent decay + channel-mix
# --------------------------------------------------------------------------
def init_rwkv(cfg: ArchConfig, key, dtype):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    nh = d // hs
    ks = jax.random.split(key, 10)
    lora = 32 if d >= 512 else 8
    return dict(
        mix_r=jnp.full((d,), 0.5, dtype), mix_k=jnp.full((d,), 0.5, dtype),
        mix_v=jnp.full((d,), 0.5, dtype), mix_w=jnp.full((d,), 0.5, dtype),
        mix_g=jnp.full((d,), 0.5, dtype),
        wr=_dense(ks[0], (d, d), dtype), wk=_dense(ks[1], (d, d), dtype),
        wv=_dense(ks[2], (d, d), dtype), wg=_dense(ks[3], (d, d), dtype),
        wo=_dense(ks[4], (d, d), dtype),
        # data-dependent decay lora: w = exp(-exp(wbase + tanh(x@w1)@w2))
        w_base=jnp.full((d,), -2.0, dtype),
        w1=_dense(ks[5], (d, lora), dtype, scale=0.01),
        w2=_dense(ks[6], (lora, d), dtype, scale=0.01),
        u=_dense(ks[7], (nh, hs), dtype, scale=0.5),     # bonus
        ln_x=jnp.ones((d,), dtype),
        ln_cm=jnp.ones((d,), dtype),                     # channel-mix norm
        # channel mix
        cmix_k=jnp.full((d,), 0.5, dtype),
        cmix_r=jnp.full((d,), 0.5, dtype),
        ck=_dense(ks[8], (d, cfg.d_ff), dtype),
        cv=_dense(ks[9], (cfg.d_ff, d), dtype),
        cr=_dense(jax.random.fold_in(key, 99), (d, d), dtype),
    )


def _rwkv_mix(x, x_prev, mu):
    return x + (x_prev - x) * mu


def rwkv_time_mix_seq(cfg: ArchConfig, p, x, return_state=False,
                      use_wkv_kernel=False):
    """WKV recurrence over time. The pure-XLA scan round-trips the matrix
    state through HBM every step (measured 2.06e15 B/dev on train_4k — the
    worst memory term in the sweep); `use_wkv_kernel=True` routes through
    the Pallas kernel (repro.kernels.wkv) that keeps the state VMEM-resident
    (interpret-mode on CPU; compiled on TPU). x: (B,S,d)."""
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    nh = d // hs
    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r = _rwkv_mix(x, xprev, p["mix_r"]) @ p["wr"]
    k = _rwkv_mix(x, xprev, p["mix_k"]) @ p["wk"]
    v = _rwkv_mix(x, xprev, p["mix_v"]) @ p["wv"]
    g = jax.nn.silu(_rwkv_mix(x, xprev, p["mix_g"]) @ p["wg"])
    xw = _rwkv_mix(x, xprev, p["mix_w"])
    w = jnp.exp(-jnp.exp((p["w_base"]
                          + jnp.tanh(xw @ p["w1"]) @ p["w2"]).astype(F32)))
    rh = r.reshape(b, s, nh, hs)
    kh = k.reshape(b, s, nh, hs)
    vh = v.reshape(b, s, nh, hs)
    wh = w.reshape(b, s, nh, hs)
    u = p["u"].astype(F32)

    if use_wkv_kernel and not return_state:
        from repro.kernels.wkv.ops import wkv_padded
        def bhfmt(a):
            return jnp.moveaxis(a, 2, 1).reshape(b * nh, s, hs)
        ub = jnp.broadcast_to(u[None], (b, nh, hs)).reshape(b * nh, hs)
        yk = wkv_padded(bhfmt(rh), bhfmt(kh), bhfmt(vh), bhfmt(wh), ub)
        y = jnp.moveaxis(yk.reshape(b, nh, s, hs), 1, 2).reshape(b, s, d)
        y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
        return (y * g) @ p["wo"], None

    def step(state, inp):
        rt, kt, vt, wt = inp                    # (B, nh, hs)
        kv = kt[..., :, None] * vt[..., None, :]            # (B,nh,hs,hs)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[..., None] * kv)
        state = wt[..., None] * state + kv
        return state, y

    st0 = jnp.zeros((b, nh, hs, hs), F32)
    st_fin, ys = jax.lax.scan(
        step, st0,
        (jnp.moveaxis(rh, 1, 0).astype(F32), jnp.moveaxis(kh, 1, 0).astype(F32),
         jnp.moveaxis(vh, 1, 0).astype(F32), jnp.moveaxis(wh, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = (y * g) @ p["wo"]
    if return_state:
        return out, (x[:, -1], st_fin)
    return out, None


def rwkv_time_mix_step(cfg: ArchConfig, p, x, state):
    """Decode form. state = (x_prev (B,d), S (B,nh,hs,hs))."""
    b = x.shape[0]
    d = x.shape[-1]
    hs = cfg.rwkv_head_size
    nh = d // hs
    xprev, st = state
    xt = x[:, 0]
    r = _rwkv_mix(xt, xprev, p["mix_r"]) @ p["wr"]
    k = _rwkv_mix(xt, xprev, p["mix_k"]) @ p["wk"]
    v = _rwkv_mix(xt, xprev, p["mix_v"]) @ p["wv"]
    g = jax.nn.silu(_rwkv_mix(xt, xprev, p["mix_g"]) @ p["wg"])
    xw = _rwkv_mix(xt, xprev, p["mix_w"])
    w = jnp.exp(-jnp.exp((p["w_base"]
                          + jnp.tanh(xw @ p["w1"]) @ p["w2"]).astype(F32)))
    rt = r.reshape(b, nh, hs).astype(F32)
    kt = k.reshape(b, nh, hs).astype(F32)
    vt = v.reshape(b, nh, hs).astype(F32)
    wt = w.reshape(b, nh, hs)
    u = p["u"].astype(F32)
    kv = kt[..., :, None] * vt[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rt, st + u[..., None] * kv)
    st = wt[..., None] * st + kv
    y = y.reshape(b, d)
    y = rms_norm(y.astype(x.dtype), p["ln_x"], cfg.norm_eps)
    out = ((y * g) @ p["wo"])[:, None, :]
    return out, (xt, st)


def rwkv_channel_mix(cfg: ArchConfig, p, x, x_prev=None):
    """x: (B,S,d) (sequence) or (B,d) with explicit x_prev (step)."""
    if x.ndim == 3:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        xprev = x_prev
    k = _rwkv_mix(x, xprev, p["cmix_k"]) @ p["ck"]
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(_rwkv_mix(x, xprev, p["cmix_r"]) @ p["cr"])
    return r * (k @ p["cv"])
