"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a fresh process (``python -m repro.launch.dryrun``): the
XLA_FLAGS below force 512 host devices and must be set before jax
initializes. Do NOT import this module from test/bench processes.

Per cell:
  - builds ShapeDtypeStruct input specs (no allocation),
  - jit(train_step | prefill_step | decode_step) with in/out shardings,
  - .lower().compile() on the production mesh,
  - records memory_analysis() + our HLO cost parse (FLOPs, bytes,
    collective bytes with while-trip multiplication) → JSON artifact.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      [--out artifacts/dryrun] [--hlo-dir artifacts/hlo]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.shapes import SHAPES, input_specs, cell_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models import sharding as Sh
from repro.optim import adamw
from repro.roofline import analysis as RA
from repro.train.train_step import make_train_step
from repro.serve.serve_step import make_prefill_step, make_decode_step
from jax.sharding import NamedSharding, PartitionSpec as P


def params_shape_tree(cfg, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of params via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: T.init_params(cfg, k, dtype=dtype), jax.random.PRNGKey(0))


def lower_cell(cfg, shape, mesh, mesh_name, opt=True, seq_chunk=512,
               save_hlo_dir=None):
    Sh.set_mesh_context(mesh)     # layer-internal sharding constraints
    pshapes = params_shape_tree(cfg)
    pspecs = Sh.param_specs(cfg, pshapes)
    specs = input_specs(cfg, shape)
    ispecs = Sh.input_spec_tree(cfg, specs, mesh)
    ns = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    t0 = time.perf_counter()

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        step = make_train_step(cfg, opt_cfg, seq_chunk=seq_chunk,
                               constrain=Sh.activation_constrainer(mesh))
        ostate_shapes = jax.eval_shape(adamw.init_state, pshapes)
        zspecs = Sh.zero_specs(pspecs, pshapes, mesh)   # ZeRO m/v over 'data'
        ospecs = adamw.AdamWState(step=P(), m=zspecs, v=zspecs)
        fn = jax.jit(
            lambda p, o, b: step(p, o, None, b)[:2],
            in_shardings=(ns(pspecs), ns(ospecs), ns(ispecs)),
            out_shardings=(ns(pspecs), ns(ospecs)),
        )
        lowered = fn.lower(pshapes, ostate_shapes, specs)
    elif shape.kind == "prefill":
        prefill = make_prefill_step(cfg)
        fn = jax.jit(
            lambda p, b: prefill(p, **b),
            in_shardings=(ns(pspecs), ns(ispecs)),
        )
        lowered = fn.lower(pshapes, specs)
    else:  # decode
        decode = make_decode_step(cfg)
        cache_specs_ = specs["cache"]
        cspecs = ispecs["cache"]

        def dec(p, tokens, cache, pos, embeds=None, positions=None):
            return decode(p, tokens, cache, pos, embeds=embeds,
                          positions=positions)

        in_sh = dict(tokens=ispecs["tokens"], cache=cspecs, pos=P())
        kwargs = dict(tokens=specs["tokens"], cache=cache_specs_,
                      pos=specs["pos"])
        if "embeds" in specs:
            in_sh["embeds"] = ispecs["embeds"]
            kwargs["embeds"] = specs["embeds"]
        if "positions" in specs:
            in_sh["positions"] = ispecs["positions"]
            kwargs["positions"] = specs["positions"]
        fn = jax.jit(
            lambda p, kw: dec(p, **kw),
            in_shardings=(ns(pspecs), ns(in_sh)),
            out_shardings=(NamedSharding(mesh, P()), ns(cspecs)),
            donate_argnums=(1,),       # cache updated in place (aliased)
        )
        lowered = fn.lower(pshapes, kwargs)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if save_hlo_dir:
        os.makedirs(save_hlo_dir, exist_ok=True)
        with open(os.path.join(
                save_hlo_dir, f"{cfg.name}__{shape.name}__{mesh_name}.hlo"),
                "w") as f:
            f.write(hlo)
    n_tokens = (shape.global_batch * shape.seq_len if shape.kind != "decode"
                else shape.global_batch * 1)
    roof = RA.compute(cfg, shape.name, shape.kind, mesh_name,
                      chips=mesh.size, hlo_text=hlo, n_tokens=n_tokens,
                      mem_stats=mem)
    rec = roof.to_dict()
    rec.update(
        t_lower_s=t_lower, t_compile_s=t_compile,
        mem_args_gib=mem.argument_size_in_bytes / 2**30,
        mem_out_gib=mem.output_size_in_bytes / 2**30,
        mem_temp_gib=mem.temp_size_in_bytes / 2**30,
        status="ok",
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--hlo-dir", default=None)
    ap.add_argument("--seq-chunk", type=int, default=512)
    args = ap.parse_args(argv)

    archs = list(registry.ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    results = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "pod2x16x16" if multi else "pod16x16"
        for an in archs:
            cfg = registry.get(an)
            for sn in shapes:
                shape = SHAPES[sn]
                ok, why = cell_applicable(cfg, shape)
                tag = f"{cfg.name} × {shape.name} × {mesh_name}"
                if not ok:
                    print(f"[skip] {tag}: {why}", flush=True)
                    results.append(dict(arch=cfg.name, shape=sn,
                                        mesh=mesh_name, status="skipped",
                                        reason=why))
                    continue
                try:
                    rec = lower_cell(cfg, shape, mesh, mesh_name,
                                     seq_chunk=args.seq_chunk,
                                     save_hlo_dir=args.hlo_dir)
                    results.append(rec)
                    print(f"[ok]   {tag}: compile={rec['t_compile_s']:.1f}s "
                          f"temp={rec['mem_temp_gib']:.2f}GiB "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll/dev={rec['coll_bytes_per_device']:.3e} "
                          f"bottleneck={rec['bottleneck']}", flush=True)
                except Exception as e:
                    traceback.print_exc()
                    results.append(dict(arch=cfg.name, shape=sn,
                                        mesh=mesh_name, status="error",
                                        error=str(e)[:500]))
                    print(f"[FAIL] {tag}: {e}", flush=True)
    out_path = os.path.join(
        args.out, f"dryrun_{'_'.join(m and 'multi' or 'single' for m in meshes)}.json")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    n_skip = sum(1 for r in results if r.get("status") == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"\nDRYRUN: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors"
          f" → {out_path}")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
