"""Multi-pod dry-run for the SOLVER itself — the paper's technique on the
production mesh.

Scenario (paper §3.2 at pod scale): a large batch of independent
same-pattern systems (Monte-Carlo / transient-sweep circuit simulation) is
factored+solved per step. The batch shards over the data axes ('pod','data');
each factorization's panel operations use the 'model' axis via the batched
vmap inner dimension (many RHS per system). This is the deployment shape of
HYLU-on-TPU: analysis once on host, numeric factorization as a compiled
static schedule, thousands of repeats.

    python -m repro.launch.solver_dryrun [--n 800] [--batch 4096] [--multi]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

# ruff: noqa: E402
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.api import analyze, HyluOptions
from repro.core.jax_engine import make_factor_fn, make_lu_solver
from repro.core.structure import build_solve_structure
from repro.launch.mesh import make_production_mesh
from repro.roofline import analysis as RA


def build_problem(n, seed=0):
    """Host-side: one representative circuit-like pattern + analysis."""
    import scipy.sparse as sp
    from repro.core.matrix import CSR
    rng = np.random.default_rng(seed)
    m = int(n * 1.5)
    rows = rng.integers(0, n, m)
    delta = rng.geometric(1.0 / 16, m)
    cols = np.clip(rows + rng.choice([-1, 1], m) * delta, 0, n - 1)
    keep = rows != cols
    a = sp.coo_matrix((rng.uniform(0.1, 10, keep.sum()),
                       (rows[keep], cols[keep])), shape=(n, n))
    a = a + a.T
    d = np.abs(a).sum(axis=1).A.ravel() + rng.uniform(0.1, 1.0, n)
    a = (sp.diags(d) - a).tocsr()
    a.sort_indices()
    return CSR.from_scipy(a), analyze(CSR.from_scipy(a), HyluOptions())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=800,
                    help="system dimension (plan is trace-unrolled)")
    ap.add_argument("--batch", type=int, default=4096,
                    help="independent systems per step (Monte-Carlo batch)")
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun/solver.json")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi)
    mesh_name = "pod2x16x16" if args.multi else "pod16x16"
    Ac, an = build_problem(args.n)
    print(f"pattern n={Ac.n} nnz={Ac.nnz} mode={an.choice.mode} "
          f"nodes={an.plan.n_nodes} levels={len(an.plan.levels)} "
          f"(bulk {an.plan.n_bulk_levels})")

    factor_fn = make_factor_fn(an.plan, dtype=jnp.float32)
    ss = build_solve_structure(an.plan)
    lu_solve, _ = make_lu_solver(ss, dtype=jnp.float32)
    src_map = jnp.asarray(an.src_map)
    scale_map = jnp.asarray(an.scale_map, dtype=jnp.float32)
    p_ = jnp.asarray(an.p)
    q_ = jnp.asarray(an.q)
    r_ = jnp.asarray(an.match.row_scale, jnp.float32)
    s_ = jnp.asarray(an.match.col_scale, jnp.float32)
    n = an.n

    def one_solve(a_data, b):
        f = factor_fn(a_data[src_map] * scale_map)
        c = (r_ * b)[p_][f.inode_perm]
        w = lu_solve(f.vals, c)
        z = jnp.zeros(n, jnp.float32).at[p_].set(w)
        y = jnp.zeros(n, jnp.float32).at[q_].set(z)
        return s_ * y

    batched = jax.vmap(one_solve)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    in_sh = (NamedSharding(mesh, P(dp, None)), NamedSharding(mesh, P(dp, None)))
    specs = (jax.ShapeDtypeStruct((args.batch, Ac.nnz), jnp.float32),
             jax.ShapeDtypeStruct((args.batch, n), jnp.float32))
    t0 = time.perf_counter()
    lowered = jax.jit(batched, in_shardings=in_sh,
                      out_shardings=NamedSharding(mesh, P(dp, None))
                      ).lower(*specs)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    c = RA.hlo_cost.analyze(hlo)
    rec = dict(
        arch=f"hylu-solver-n{args.n}", shape=f"batch{args.batch}",
        mesh=mesh_name, chips=mesh.size, status="ok",
        t_lower_s=t_lower, t_compile_s=t_compile,
        mem_temp_gib=mem.temp_size_in_bytes / 2**30,
        mem_args_gib=mem.argument_size_in_bytes / 2**30,
        flops_per_device=c.flops, bytes_per_device=c.bytes_accessed,
        coll_bytes_per_device=c.coll_bytes,
        coll_by_kind=dict(c.coll_by_kind),
        t_compute=c.flops / RA.PEAK_FLOPS,
        t_memory=c.bytes_accessed / RA.HBM_BW,
        t_collective=c.coll_bytes / RA.LINK_BW,
        useful_flops_per_system=an.plan.useful_flops,
        padded_flops_per_system=an.plan.padded_flops,
    )
    rec["bottleneck"] = max(
        ("compute", "memory", "collective"),
        key=lambda k: rec[f"t_{k}"])
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps({k: v for k, v in rec.items()
                      if k != "coll_by_kind"}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
