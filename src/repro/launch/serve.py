"""Serving launcher: batched greedy generation demo over the public API.

    PYTHONPATH=src python -m repro.launch.serve --arch musicgen-medium \
        --reduced --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           dtype=jnp.float32)
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)
    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    tok_s = args.batch * args.new_tokens / dt
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({tok_s:.1f} tok/s, batch={args.batch})")
    print("sample:", np.asarray(out[0])[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
