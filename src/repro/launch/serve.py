"""Serve: the async solver-server entrypoint with a built-in load generator.

Stands up an :class:`AsyncSolverServer` over a :class:`SolverService` and
drives it with the fault-injection harness's mixed-pattern stream
(``repro.serve.faultinject``) — healthy circuit/banded/denseish systems
interleaved with the full fault matrix at ``--fault-rate``.  Prints a
serving report (throughput, p50/p99 latency, deadline-miss / reject /
quarantine rates, per-status outcome counts) and exits nonzero if the
robustness contract is violated (a lost request, a silently-wrong
solution, or a healthy request off fp64-oracle parity).

    PYTHONPATH=src python -m repro.launch.serve --requests 200 \
        --batch-size 8 --fault-rate 0.2 --deadline-ms 200

This is the runnable face of ROADMAP item 3; the ``--serving-async``
section of ``benchmarks/bench_factor_repeated.py`` records the same
numbers into BENCH_repeated.json for the perf trajectory.
"""
from __future__ import annotations

import argparse
import asyncio
import sys
import time


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--requests", type=int, default=500,
                   help="stream length (default 500)")
    p.add_argument("--n", type=int, default=32,
                   help="system size per request (default 32)")
    p.add_argument("--batch-size", type=int, default=8,
                   help="dispatch batch size (default 8)")
    p.add_argument("--fault-rate", type=float, default=0.2,
                   help="fraction of the stream replaced by injected "
                        "faults (default 0.2; 0 = pure healthy load)")
    p.add_argument("--deadline-ms", type=float, default=None,
                   help="default per-request latency budget (default: "
                        "none)")
    p.add_argument("--max-queue-per-group", type=int, default=64,
                   help="bounded per-pattern queue depth (default 64)")
    p.add_argument("--max-pending", type=int, default=1024,
                   help="global admission bound (default 1024)")
    p.add_argument("--max-linger-ms", type=float, default=50.0,
                   help="flush a non-empty window at most this long after "
                        "its oldest request arrived (default 50)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--devices", type=int, default=None,
                   help="shard dispatches over the first N jax devices")
    return p


async def _serve_and_drive(args) -> dict:
    from repro.core.options import HyluOptions
    from repro.serve.solver_service import SolverService
    from repro.serve.async_server import AsyncSolverServer
    from repro.serve import faultinject

    opts = HyluOptions(deadline_ms=args.deadline_ms,
                       mesh=(args.devices if args.devices
                             and args.devices > 1 else None))
    service = SolverService(opts=opts, cache_dir=None,
                            batch_size=args.batch_size)
    stream = faultinject.make_stream(args.requests,
                                     fault_rate=args.fault_rate,
                                     seed=args.seed, n=args.n)
    async with AsyncSolverServer(
            service,
            max_queue_per_group=args.max_queue_per_group,
            max_pending=args.max_pending,
            max_linger_ms=args.max_linger_ms,
            default_deadline_ms=args.deadline_ms) as server:
        t0 = time.perf_counter()
        report = await faultinject.run_stream(server, stream)
        report["wall_s"] = time.perf_counter() - t0
    return report


def print_report(report: dict, file=sys.stdout) -> None:
    s = report["server_stats"]
    n = report["n_requests"]
    wall = report.get("wall_s") or 1e-9

    def fmt(v, spec=".2f"):
        return "n/a" if v is None else format(v, spec)

    print(f"serve: {n} requests in {wall:.2f}s "
          f"({n / wall:.1f} req/s)", file=file)
    print(f"  outcomes: {report['by_status']}", file=file)
    print(f"  lost: {report['lost']}   "
          f"healthy fp64-oracle worst rel err: "
          f"{report['worst_healthy_err']:.3e} "
          f"({report['n_healthy_checked']} checked)", file=file)
    print(f"  latency: p50 {fmt(s['p50_ms'])} ms, p99 {fmt(s['p99_ms'])} ms"
          f"   deadline-miss rate: {s['deadline_miss_rate']:.3f}",
          file=file)
    print(f"  reject rate: {s['reject_rate']:.3f} "
          f"(queue-full {s['rejected_full']}, "
          f"invalid {s['rejected_invalid']})   "
          f"retries: {s['retries']}   quarantined: {s['quarantined']}",
          file=file)
    print(f"  dispatch batches: {s['dispatch_batches']}   "
          f"queue depth at exit: {s['queue_depth']}", file=file)


def main(argv=None) -> int:
    import jax
    jax.config.update("jax_enable_x64", True)

    args = build_parser().parse_args(argv)
    report = asyncio.run(_serve_and_drive(args))
    print_report(report)

    from repro.serve.faultinject import check_report
    violations = check_report(report)
    if violations:
        print(f"\nFAIL: {len(violations)} robustness-contract "
              f"violation(s):", file=sys.stderr)
        for v in violations[:20]:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print("\nOK: every request got exactly one terminal result; healthy "
          "traffic at fp64-oracle parity.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
