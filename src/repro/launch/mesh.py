"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).

Two families live here:

* the training/dryrun meshes (``make_production_mesh`` / ``make_host_mesh``),
  kept from the transformer substrate;
* the solver's 1-D **system-batch mesh** (``make_solver_mesh``) that the
  batched repeated-solve engine shards over — the K independent systems of
  ``factor_batched`` / ``solve_batched`` / ``solve_sequence`` are
  embarrassingly parallel, so a single data axis is the whole story — plus
  the virtual-CPU-device harness (``ensure_virtual_cpu_devices``) that lets
  tests and CI exercise multi-device sharding on one host.
"""
from __future__ import annotations

import os

import jax

#: mesh axis name the batched solver shards the system-batch dimension over
BATCH_AXIS = "systems"


def compat_make_mesh(shape, axes):
    """jax.make_mesh across versions: axis_types/AxisType only exist on
    newer jax; older versions default to Auto semantics anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (fake or real) devices exist — used by
    distributed smoke tests."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def make_solver_mesh(n_devices: int | None = None, axis: str = BATCH_AXIS):
    """1-D mesh over the system-batch axis of the batched solver.

    ``n_devices=None`` takes every visible device; an int takes the first
    ``n_devices`` (so a sweep over device counts on one host is just
    ``make_solver_mesh(1), make_solver_mesh(2), ...``).  The returned mesh
    is what ``HyluOptions.mesh`` accepts directly — passing an int there
    routes through this helper."""
    import numpy as np

    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"make_solver_mesh: asked for {n} devices but "
            f"{len(devs)} are visible — on CPU, force virtual devices with "
            "launch.mesh.ensure_virtual_cpu_devices(n) (or XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}) before jax "
            "initializes its backend")
    return jax.sharding.Mesh(np.asarray(devs[:n]), (axis,))


def ensure_virtual_cpu_devices(n: int) -> int:
    """Force ≥ ``n`` virtual CPU devices (the multi-device test/CI harness).

    XLA reads ``--xla_force_host_platform_device_count`` exactly once, when
    the CPU backend initializes — so this must run before anything touches
    ``jax.devices()`` / puts an array on device.  Returns the resulting
    device count; raises if the backend already initialized with fewer
    devices than requested (the caller should set ``XLA_FLAGS`` in the
    environment, or run in a subprocess — see tests/test_sharding.py)."""
    n = int(n)
    try:
        from jax._src import xla_bridge as _xb
        initialized = bool(getattr(_xb, "_backends", None))
    except Exception:                        # private API moved: probe hard
        initialized = True
    if not initialized:
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                flags + f" --xla_force_host_platform_device_count={n}"
            ).strip()
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"need {n} devices but jax initialized with {have}; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} before "
            "importing/using jax (e.g. in a fresh subprocess)")
    return have
