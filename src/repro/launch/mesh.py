"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """jax.make_mesh across versions: axis_types/AxisType only exist on
    newer jax; older versions default to Auto semantics anyway."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (fake or real) devices exist — used by
    distributed smoke tests."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))
