"""Production mesh definitions.

A function, not a module-level constant: importing this module must never
touch jax device state (smoke tests see 1 device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many (fake or real) devices exist — used by
    distributed smoke tests."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
