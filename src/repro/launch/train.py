"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-medium-14b \
        --reduced --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ck

Runs on whatever devices exist (1 CPU here; the same entry point on a TPU
pod slice picks up the full mesh via jax.distributed).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.pipeline import SyntheticLM
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim.compression import CompressionConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-medium-14b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed),
                           dtype=jnp.float32)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=args.seed)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, microbatch=args.microbatch,
                         seq_chunk=min(512, args.seq))
    trainer = Trainer(tcfg, cfg, params, data,
                      opt_cfg=adamw.AdamWConfig(
                          lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5)),
                      comp_cfg=CompressionConfig(kind=args.compress))
    trainer.install_signal_handler()
    if args.resume:
        r = trainer.maybe_resume()
        print(f"resumed from step {r}" if r is not None else "fresh start")
    log = trainer.run()
    if log:
        print(f"final loss {log[-1]['loss']:.4f} "
              f"(first {log[0]['loss']:.4f}); stragglers={trainer.n_stragglers}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
