"""Repeated-solve benchmark (paper Fig 8 scenario + the batched JAX engine).

One analysis, K refactorizations (+ solves) of the same sparsity pattern
with drifting values — the circuit-simulation workload HYLU's headline
2.90× repeated-factorization speedup comes from.  Three engines:

  looped-ref   K × ref_engine.factor in a Python loop (numpy reference)
  jitted-jax   K × pre-compiled XLA refactor calls (engine="jax")
  batched-jax  one vmapped XLA program for all K (factor_batched)

plus a solve-phase section comparing the fused on-device batched solve
(substitution + CSR residual matvec + the whole refinement loop as ONE
XLA program, `solve_batched`) against the pre-fusion host-loop baseline
(`api._solve_batched_hostloop`: one host round-trip per refinement
iteration).

Compile time is reported first-class: compile_scalar_s / compile_batched_s
per matrix plus their geomeans in the summary, and a compile-vs-run table
(also written next to the JSON) — the level-bucketed factor trace lives or
dies by this number.  ``--large`` adds the circuit_2000-scale matrices
that only compile at all with the bucketed trace; ``--jax-cache DIR``
points the persistent JAX compilation cache somewhere (default
``$JAX_COMPILATION_CACHE_DIR`` or ``.jax_cache``; pass '' to disable —
recorded compile numbers are only *cold* numbers with a fresh/disabled
cache).

The ``analyze`` section records the host preprocessing phase per matrix
(matching/ordering/symbolic/plan breakdown) plus plan-cache cold vs warm
timings (in-memory hit and disk-artifact load — what a fresh process pays
instead of re-analyzing), and the ``serving`` section measures an
interleaved circuit/banded/unsym mixed-pattern request stream through
``SolverService`` (cold analyze+compile vs warm cache hits, req/s).

``--devices N`` adds the multi-device sweep: the batched refactor+solve
on a 1-D solver mesh over 1, 2, …, N (virtual CPU) devices
(``HyluOptions(mesh=d)``), recorded as the ``devices_sweep`` section —
batched refactor throughput (systems/s) vs device count.  Virtual
devices are forced before jax initializes, so ``--devices`` must be
handled by this process from the start (it is).

Writes BENCH_repeated.json (per-matrix timings + geomean speedups over
looped-ref) so successive PRs have a perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_factor_repeated \
        [--k 32] [--quick] [--large] [--jax-cache DIR] [--devices N]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import CSR, analyze, factor, refactor, solve
from repro.core.api import (factor_batched, solve_batched,
                            _solve_batched_hostloop, jax_repeated_engine)
from repro.core.ref_engine import factor_value_loop

from . import matrices


def _geomean(xs):
    xs = [x for x in xs if x and np.isfinite(x) and x > 0]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


def _value_drift(data, k, rng):
    """K value sets with the mild drift of Newton/transient sequences."""
    return data[None, :] * rng.uniform(0.9, 1.1, (k, len(data)))


def bench_matrix(name, Ac, k):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    an = analyze(Ac)
    vb = _value_drift(Ac.data, k, rng)
    bb = rng.normal(size=(k, Ac.n))
    mats = [CSR(Ac.n, Ac.indptr, Ac.indices, vb[i]) for i in range(k)]
    rec = dict(n=Ac.n, nnz=Ac.nnz, mode=an.choice.mode, k=k)

    # ---- looped-ref: numeric refactorization only, then end-to-end --------
    mb = vb[:, an.src_map] * an.scale_map
    t0 = time.perf_counter()
    factor_value_loop(an.plan, an.m_pattern, mb,
                      perturb_eps=an.opts.perturb_eps)
    rec["refac_ref_loop_s"] = time.perf_counter() - t0

    st = factor(an, Ac, engine="ref")
    t0 = time.perf_counter()
    for i in range(k):
        st_i = refactor(st, mats[i])
        solve(st_i, bb[i])
    rec["end2end_ref_loop_s"] = time.perf_counter() - t0

    # ---- jitted-jax: compile once, K scalar pre-compiled calls ------------
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    st_j = factor(an, Ac, engine="jax")          # triggers refactor compile
    solve(st_j, bb[0])                           # triggers apply compile
    rec["compile_scalar_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(k):
        jf = eng.refactor(jnp.asarray(vb[i]))
    jax.block_until_ready(jf.vals)
    rec["refac_jax_jit_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(k):
        st_i = refactor(st_j, mats[i])
        solve(st_i, bb[i])
    rec["end2end_jax_jit_s"] = time.perf_counter() - t0

    # ---- batched-jax: one vmapped XLA program for all K -------------------
    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)             # includes vmap compile
    x, info = solve_batched(bst, bb)
    rec["compile_batched_s"] = time.perf_counter() - t0
    assert float(info["residual"].max()) < 1e-8, (name, info["residual"].max())

    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)
    rec["refac_jax_batched_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    rec["end2end_jax_batched_s"] = time.perf_counter() - t0

    # ---- solve phase: fused on-device refinement vs the host-loop baseline
    # (device substitution + numpy residual matvec + Python refine loop).
    # best-of-N timing: these are millisecond-scale calls on a shared
    # machine, where a mean is dominated by scheduler noise ----------------
    reps = 10

    def _best(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    _solve_batched_hostloop(bst, bb)             # warm the scalar apply path
    rec["solve_hostloop_s"] = _best(lambda: _solve_batched_hostloop(bst, bb))
    x, info = solve_batched(bst, bb)             # fused program is compiled
    rec["solve_fused_s"] = _best(lambda: solve_batched(bst, bb))
    rec["solve_n_refine"] = int(info["n_refine"])
    rec["speedup_solve_fused"] = (rec["solve_hostloop_s"]
                                  / rec["solve_fused_s"])
    # the fused on-device solve must not lose to the host loop even when
    # refinement doesn't iterate (0.9: timing-jitter allowance).  Guarded
    # on the core suite at production batch sizes only: below K≈16 the
    # lax.while_loop's fixed ~0.3 ms overhead dominates sub-ms solves, and
    # the --large matrices' multi-hundred-ms solves swing tens of percent
    # with machine load (informational there).  Recorded per matrix and
    # raised only after the whole suite is written out, so one noisy
    # sample can't discard the run's results.
    rec["solve_fused_ok"] = (k < 16 or Ac.n > 1000
                             or rec["speedup_solve_fused"] >= 0.9)

    # refinement-engaged: tol=0 forces the loop to iterate until it stalls,
    # so the per-iteration host round-trip of the baseline is actually on
    # the clock (tol is a dynamic arg — no recompile)
    tol_saved = an.opts.refine_tol
    an.opts.refine_tol = 0.0
    try:
        _solve_batched_hostloop(bst, bb, refine=True)
        rec["solve_refined_hostloop_s"] = _best(
            lambda: _solve_batched_hostloop(bst, bb, refine=True))
        _, info_f = solve_batched(bst, bb, refine=True)
        rec["solve_refined_fused_s"] = _best(
            lambda: solve_batched(bst, bb, refine=True))
        rec["solve_refined_n_iter"] = int(info_f["n_refine"])
        rec["speedup_solve_refined_fused"] = (
            rec["solve_refined_hostloop_s"] / rec["solve_refined_fused_s"])
    finally:
        an.opts.refine_tol = tol_saved

    for which in ("jax_jit", "jax_batched"):
        rec[f"speedup_refac_{which}"] = (rec["refac_ref_loop_s"]
                                         / rec[f"refac_{which}_s"])
        rec[f"speedup_end2end_{which}"] = (rec["end2end_ref_loop_s"]
                                           / rec[f"end2end_{which}_s"])
    return rec


def bench_analyze_matrix(name, Ac, cache_root=None):
    """Analyze-phase benchmark for one matrix: the host preprocessing
    breakdown (matching / ordering / symbolic / plan) plus plan-cache
    timings — cold (analyze + persist), warm in-memory hit, and warm disk
    hit from a fresh cache over the same ``checkpoints/``-style artifact
    store (a fresh process pays only this load instead of the analyze).

    cache_root: directory for the throwaway artifact store; None creates
    (and removes) a fresh temp dir."""
    import shutil

    from repro.core import HyluOptions
    from repro.core.plan_cache import PlanCache

    own_root = cache_root is None
    if own_root:
        cache_root = tempfile.mkdtemp(prefix="hylu_bench_plan_cache_")
    d = os.path.join(cache_root, name)
    shutil.rmtree(d, ignore_errors=True)
    opts = HyluOptions()
    cache = PlanCache(directory=d)

    t0 = time.perf_counter()
    an = cache.get_or_analyze(Ac, opts)          # cold: analyze + save
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cache.get_or_analyze(Ac, opts)               # warm: in-memory hit
    warm_mem_s = time.perf_counter() - t0
    fresh = PlanCache(directory=d)
    t0 = time.perf_counter()
    an2 = fresh.get_or_analyze(Ac, opts)         # warm: disk artifact load
    warm_disk_s = time.perf_counter() - t0
    assert fresh.stats["disk_hits"] == 1 and fresh.stats["analyze_calls"] == 0
    rec = dict(
        n=Ac.n, nnz=Ac.nnz, mode=an.choice.mode,
        analyze_s=dict(
            matching=an.timings["matching"], ordering=an.timings["ordering"],
            symbolic=an.timings["symbolic"], plan=an.timings["plan"],
            total=an.timings["total"]),
        plan_cache=dict(cold_s=cold_s, warm_mem_s=warm_mem_s,
                        warm_disk_s=warm_disk_s,
                        artifact_bytes=os.path.getsize(fresh.path_for(
                            an2.fingerprint)),
                        speedup_warm_disk=an.timings["total"] / warm_disk_s),
    )
    print(f"[analyze]  {name:14s} n={Ac.n:5d} "
          f"analyze={an.timings['total']*1e3:7.1f}ms "
          f"(match={an.timings['matching']*1e3:6.1f} "
          f"order={an.timings['ordering']*1e3:6.1f} "
          f"sym={an.timings['symbolic']*1e3:6.1f} "
          f"plan={an.timings['plan']*1e3:6.1f}) "
          f"cache cold={cold_s*1e3:7.1f}ms mem={warm_mem_s*1e6:5.0f}us "
          f"disk={warm_disk_s*1e3:6.1f}ms "
          f"({rec['plan_cache']['speedup_warm_disk']:.1f}x vs analyze)",
          flush=True)
    if own_root:
        shutil.rmtree(cache_root, ignore_errors=True)
    return rec


def bench_serving(k_per_pattern=8, reps=2, batch_size=8, cache_root=None):
    """Mixed-pattern serving throughput: an interleaved stream of circuit /
    banded / unsym requests (distinct sparsity patterns, per-request value
    drift) through ``SolverService`` — cold (analyze + compile on first
    touch of each pattern) vs warm (every plan and engine cached).

    cache_root: directory to put the run's throwaway plan-cache store
    under; None creates (and owns) a fresh temp dir."""
    import shutil

    from repro.serve.solver_service import SolverService, SolveRequest

    own_root = cache_root is None
    if own_root:
        cache_root = tempfile.mkdtemp(prefix="hylu_bench_serving_")
    d = os.path.join(cache_root, "serving")
    shutil.rmtree(d, ignore_errors=True)
    pats = [("circuit", CSR.from_scipy(matrices.circuit_like(200, 1)
                                       .tocsr())),
            ("banded", CSR.from_scipy(matrices.banded(150, 6, 2).tocsr())),
            ("unsym", CSR.from_scipy(matrices.unsym_random(120, 0.02, 8)
                                     .tocsr()))]

    def stream(seed):
        rng = np.random.default_rng(seed)
        reqs = []
        for rep in range(reps):
            for _ in range(k_per_pattern):
                for name, Ac in pats:
                    reqs.append(SolveRequest(
                        a=CSR(Ac.n, Ac.indptr, Ac.indices,
                              Ac.data * rng.uniform(0.9, 1.1, Ac.nnz)),
                        b=rng.normal(size=Ac.n), tag=name))
        rng.shuffle(reqs)                       # genuinely interleaved
        return reqs

    svc = SolverService(cache_dir=d, batch_size=batch_size)
    reqs = stream(1)
    t0 = time.perf_counter()
    res = svc.solve_batch(reqs)
    cold_s = time.perf_counter() - t0
    worst = max(float(np.max(r.residual)) for r in res)
    assert worst < 1e-8, worst
    reqs2 = stream(2)
    t0 = time.perf_counter()
    svc.solve_batch(reqs2)
    warm_s = time.perf_counter() - t0
    rec = dict(
        n_requests=len(reqs), n_patterns=len(pats),
        batch_size=batch_size,
        patterns={name: dict(n=Ac.n, nnz=Ac.nnz) for name, Ac in pats},
        modes=sorted(svc.pattern_modes.values()),
        cold_s=cold_s, warm_s=warm_s,
        cold_req_per_s=len(reqs) / cold_s,
        warm_req_per_s=len(reqs2) / warm_s,
        worst_residual=worst,
        dispatches=svc.stats["dispatches"],
        padded_systems=svc.stats["padded_systems"],
        plan_cache=dict(svc.cache.stats),
    )
    print(f"[serving]  {len(reqs)} mixed requests over {len(pats)} patterns "
          f"(batch={batch_size}): cold={cold_s:5.1f}s "
          f"({rec['cold_req_per_s']:6.1f} req/s) "
          f"warm={warm_s:5.2f}s ({rec['warm_req_per_s']:7.1f} req/s) "
          f"worst_resid={worst:.1e}", flush=True)
    if own_root:
        shutil.rmtree(cache_root, ignore_errors=True)
    return rec


def bench_serving_async(n_requests=520, batch_size=8, fault_rate=0.15,
                        n=32, seed=0, deadline_ms=200.0,
                        max_linger_ms=20.0):
    """The ``serving_async`` section: the continuous-batching async server
    (AsyncSolverServer) under a >=500-request mixed-pattern load-generator
    stream laced with the full fault matrix (``serve.faultinject``).
    Records steady-state throughput (req/s), p50/p99 latency,
    deadline-miss / reject / quarantine rates, and the robustness contract
    (zero lost requests, healthy traffic at fp64-oracle parity) — the
    serving tier's perf trajectory."""
    import asyncio

    from repro.serve.solver_service import SolverService
    from repro.serve.async_server import AsyncSolverServer
    from repro.serve import faultinject

    async def _run():
        service = SolverService(cache_dir=None, batch_size=batch_size)
        server = AsyncSolverServer(
            service,
            max_queue_per_group=n_requests,   # load generator submits the
            max_pending=n_requests + 8,       # whole stream up front —
            #                                   backpressure rejects would
            #                                   pollute the throughput number
            max_linger_ms=max_linger_ms,
            default_deadline_ms=deadline_ms)
        async with server:
            stream = faultinject.make_stream(
                n_requests, fault_rate=fault_rate, seed=seed, n=n)
            # warm analyze + engine compile outside the timed window (one
            # healthy request per (pattern, RHS-shape) group), so req/s and
            # the percentiles are steady-state serving numbers
            seen = set()
            for item in stream:
                if item.kind is not None:
                    continue
                key = (id(item.a.indptr), item.b.shape[1:])
                if key not in seen:
                    seen.add(key)
                    await server.solve(item.a, item.b, tag=("warmup",))
            server._latencies_ms.clear()
            t0 = time.perf_counter()
            report = await faultinject.run_stream(server, stream,
                                                  warmup=False)
            report["wall_s"] = time.perf_counter() - t0
        return report

    report = asyncio.run(_run())
    violations = faultinject.check_report(report)
    s = report["server_stats"]
    rec = dict(
        n_requests=n_requests, batch_size=batch_size, fault_rate=fault_rate,
        deadline_ms=deadline_ms, wall_s=report["wall_s"],
        req_per_s=n_requests / report["wall_s"],
        p50_ms=s["p50_ms"], p99_ms=s["p99_ms"],
        deadline_miss_rate=s["deadline_miss_rate"],
        reject_rate=s["reject_rate"],
        retries=s["retries"], quarantined=s["quarantined"],
        failed=s["failed"], dispatch_batches=s["dispatch_batches"],
        statuses=report["by_status"],
        lost=report["lost"], zero_lost=report["lost"] == 0,
        worst_healthy_err=report["worst_healthy_err"],
        n_healthy_checked=report["n_healthy_checked"],
        n_violations=len(violations),
    )
    print(f"[serving-async] {n_requests} requests "
          f"(fault_rate={fault_rate:.2f}, batch={batch_size}): "
          f"{rec['req_per_s']:7.1f} req/s "
          f"p50={rec['p50_ms']:.1f}ms p99={rec['p99_ms']:.1f}ms "
          f"miss={rec['deadline_miss_rate']:.3f} "
          f"reject={rec['reject_rate']:.3f} "
          f"quarantined={rec['quarantined']} "
          f"healthy_err={rec['worst_healthy_err']:.1e} "
          f"lost={rec['lost']}", flush=True)
    if violations:
        raise AssertionError(
            f"serving-async robustness contract violated "
            f"({len(violations)}): " + "; ".join(violations[:5]))
    return rec


def _peak_rss_mb() -> float:
    """Process high-water resident set in MB (linux ru_maxrss is KB).
    Monotone — per-phase snapshots record the watermark *after* each
    phase, so ``phase_peaks[p]`` is "the largest the process ever got up
    to and including p", and the increments attribute growth to phases."""
    import resource
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def bench_corpus_entry(entry, k=4, amalg_fill_tol=0.2, cache_root=None):
    """One corpus matrix through the scale lane: analyze (amalgamation on)
    + bucketed-schedule build + batched-engine compile + steady-state
    batched refactor + fused solve, recording runtime, the peak-RSS
    watermark after every phase, the plan's deterministic byte accounting
    (``memory_stats``) and the pad-waste / bulk-coverage numbers that
    drive sub-bucket tuning.  ``analyze_only`` entries stop after the
    schedule build (past the XLA compile budget)."""
    import jax
    import jax.numpy as jnp

    from repro.core import HyluOptions
    from repro.core.plan import plan_stats

    try:
        from . import corpus as corpus_mod
    except ImportError:
        import corpus as corpus_mod

    peaks = {"start": _peak_rss_mb()}
    t0 = time.perf_counter()
    Ac, _, meta = corpus_mod.load_entry(entry, root=cache_root)
    load_s = time.perf_counter() - t0
    peaks["load"] = _peak_rss_mb()

    opts = HyluOptions(amalg_fill_tol=amalg_fill_tol,
                       orderings=("natural", "min_degree"))
    t0 = time.perf_counter()
    an = analyze(Ac, opts)
    analyze_s = time.perf_counter() - t0
    peaks["analyze"] = _peak_rss_mb()

    t0 = time.perf_counter()
    ps = plan_stats(an.plan, bulk_min_width=opts.bulk_min_width)
    schedule_s = time.perf_counter() - t0
    peaks["schedule"] = _peak_rss_mb()

    amalg = an.choice.stats.get("amalg", {})
    rec = dict(
        meta=meta, k=k, mode=an.choice.mode, ordering=an.ordering_name,
        amalg_fill_tol=amalg_fill_tol, amalg=amalg,
        load_s=load_s, analyze_s=analyze_s, schedule_s=schedule_s,
        analyze_timings={name: round(v, 4)
                         for name, v in an.timings.items()},
        plan=dict(n_nodes=ps["n_nodes"], n_levels=ps["n_levels"],
                  n_scanned_levels=ps.get("n_scanned_levels"),
                  total_slots=ps["total_slots"],
                  pad_waste_frac=ps.get("pad_waste_frac"),
                  bulk_node_coverage=ps.get("bulk_node_coverage"),
                  mean_panel_width=ps["mean_panel_width"]),
        memory_bytes={f: ps[f] for f in
                      ("panel_bytes", "workspace_bytes",
                       "schedule_index_bytes", "batched_bytes",
                       "total_bytes") if f in ps},
    )
    if entry.analyze_only:
        rec["peak_rss_mb"] = {p: round(v, 1) for p, v in peaks.items()}
        print(f"[large] {entry.name:14s} n={meta['n']:6d} "
              f"({meta['source']}) analyze={analyze_s:6.1f}s "
              f"schedule={schedule_s:5.1f}s nodes={ps['n_nodes']} "
              f"levels={ps['n_levels']} ANALYZE-ONLY "
              f"peakRSS={peaks['schedule']:.0f}MB", flush=True)
        return rec

    rng = np.random.default_rng(0)
    vb = _value_drift(Ac.data, k, rng)
    bb = rng.normal(size=(k, Ac.n))
    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)              # batched refactor compile
    x, info = solve_batched(bst, bb)              # fused solve compile
    compile_s = time.perf_counter() - t0
    peaks["compile"] = _peak_rss_mb()
    worst = float(np.max(info["residual"]))
    assert worst < 1e-8, (entry.name, worst)

    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)              # steady-state refactor
    refac_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    x, info = solve_batched(bst, bb)
    solve_s = time.perf_counter() - t0
    peaks["run"] = _peak_rss_mb()

    eng = jax_repeated_engine(an)
    rec.update(
        compile_s=compile_s, refac_batched_s=refac_s, solve_fused_s=solve_s,
        refac_systems_per_s=k / refac_s, worst_residual=worst,
        engine_memory_bytes=eng.memory_stats(k=k),
        peak_rss_mb={p: round(v, 1) for p, v in peaks.items()},
    )
    print(f"[large] {entry.name:14s} n={meta['n']:6d} ({meta['source']}) "
          f"analyze={analyze_s:6.1f}s compile={compile_s:6.1f}s "
          f"refac={refac_s:6.2f}s solve={solve_s:5.2f}s "
          f"padwaste={ps.get('pad_waste_frac', 0):.2f} "
          f"amalg {amalg.get('n_nodes_before', ps['n_nodes'])}->"
          f"{amalg.get('n_nodes_after', ps['n_nodes'])} "
          f"peakRSS={peaks['run']:.0f}MB resid={worst:.1e}", flush=True)
    return rec


def bench_corpus(k=4, smoke=False, amalg_fill_tol=0.2, cache_root=None):
    """The ``--large`` scale lane: the SuiteSparse-class corpus
    (real matrices when reachable, statistics-matched synthetic stand-ins
    offline) end-to-end with amalgamation on.  ``smoke`` restricts to the
    CI subset (one circuit-class + one FEM-class matrix at n>=10^4)."""
    try:
        from . import corpus as corpus_mod
    except ImportError:
        import corpus as corpus_mod

    entries = (corpus_mod.smoke_corpus() if smoke else corpus_mod.corpus())
    recs = {}
    for entry in entries:
        recs[entry.name] = bench_corpus_entry(
            entry, k=k, amalg_fill_tol=amalg_fill_tol, cache_root=cache_root)
    full = [r for r in recs.values() if "refac_batched_s" in r]
    return dict(
        smoke=bool(smoke), amalg_fill_tol=amalg_fill_tol,
        matrices=recs,
        geomean=dict(
            analyze_s=_geomean([r["analyze_s"] for r in recs.values()]),
            compile_s=_geomean([r["compile_s"] for r in full]),
            refac_batched_s=_geomean([r["refac_batched_s"] for r in full]),
            pad_waste_frac=_geomean(
                [r["plan"]["pad_waste_frac"] for r in recs.values()
                 if r["plan"].get("pad_waste_frac")]),
        ),
        peak_rss_mb=max((r["peak_rss_mb"].get("run",
                                              r["peak_rss_mb"]["schedule"])
                         for r in recs.values()), default=0.0),
    )


def bench_mixed_precision_matrix(name, Ac, k, reps=10):
    """fp32-factor + fp64-refine vs pure fp64 on one matrix: steady-state
    batched refactor and fused-solve times per dtype, the plan-derived
    factor-panel bytes (the memory the reduced precision halves), how many
    refinement iterations the fp64 recovery costs, the fp64-fallback rate,
    and solution parity against the fp64 path."""
    from repro.core import HyluOptions

    rng = np.random.default_rng(0)
    vb = _value_drift(Ac.data, k, rng)
    bb = rng.normal(size=(k, Ac.n))

    def _best(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    rec = dict(n=Ac.n, nnz=Ac.nnz, k=k, dtypes={})
    xs = {}
    for dt in ("float64", "float32"):
        an = analyze(Ac, HyluOptions(factor_dtype=dt))
        t0 = time.perf_counter()
        bst = factor_batched(an, Ac, vb)          # batched refactor compile
        x, info = solve_batched(bst, bb)          # fused solve compile
        compile_s = time.perf_counter() - t0
        refac_s = _best(lambda: factor_batched(an, Ac, vb))
        bst = factor_batched(an, Ac, vb)
        solve_s = _best(lambda: solve_batched(bst, bb))
        x, info = solve_batched(bst, bb)
        xs[dt] = x
        eng = jax_repeated_engine(an)
        rec["dtypes"][dt] = dict(
            mode=an.choice.mode, compile_s=compile_s,
            refac_batched_s=refac_s, solve_fused_s=solve_s,
            n_refine=int(info["n_refine"]),
            n_refine_per_system_max=int(
                np.max(info["n_refine_per_system"])),
            worst_residual=float(np.max(info["residual"])),
            n_refine_failed=int(np.sum(info["refine_failed"])),
            n_fp64_fallback=int(info["n_fp64_fallback"]),
            fallback_rate=float(info["n_fp64_fallback"]) / k,
            factor_panel_bytes=eng.memory_stats(k=k)["panel_bytes"],
        )
    r64, r32 = rec["dtypes"]["float64"], rec["dtypes"]["float32"]
    scale = float(np.abs(xs["float64"]).max()) + 1e-30
    rec["x_diff_vs_fp64"] = float(
        np.abs(xs["float32"] - xs["float64"]).max()) / scale
    rec["speedup_refac_fp32"] = (r64["refac_batched_s"]
                                 / r32["refac_batched_s"])
    rec["speedup_solve_fp32"] = r64["solve_fused_s"] / r32["solve_fused_s"]
    rec["panel_bytes_ratio"] = (r32["factor_panel_bytes"]
                                / r64["factor_panel_bytes"])
    print(f"[mixed]    {name:14s} n={rec['n']:5d} "
          f"refac fp64={r64['refac_batched_s']*1e3:7.1f}ms "
          f"fp32={r32['refac_batched_s']*1e3:7.1f}ms "
          f"({rec['speedup_refac_fp32']:.2f}x) "
          f"solve {rec['speedup_solve_fp32']:.2f}x "
          f"bytes={rec['panel_bytes_ratio']:.2f} "
          f"resid={r32['worst_residual']:.1e} "
          f"fallback={r32['fallback_rate']:.2f} "
          f"xdiff={rec['x_diff_vs_fp64']:.1e}", flush=True)
    return rec


def bench_mixed_precision(k=32, quick=False):
    """The ``mixed_precision`` section: fp32-factor + fp64-refine over the
    main suite — refactor/solve speedups over pure fp64, the halved
    factor-panel bytes, fp64-quality residual parity, and the fp64-fallback
    rate (healthy suite matrices should never trip the escape hatch)."""
    recs = {}
    for name, Ac in suite(quick=quick):
        recs[name] = bench_mixed_precision_matrix(name, Ac, k)
    fp32 = [r["dtypes"]["float32"] for r in recs.values()]
    return dict(
        k=k, matrices=recs,
        geomean=dict(
            speedup_refac_fp32=_geomean(
                [r["speedup_refac_fp32"] for r in recs.values()]),
            speedup_solve_fp32=_geomean(
                [r["speedup_solve_fp32"] for r in recs.values()]),
            panel_bytes_ratio=_geomean(
                [r["panel_bytes_ratio"] for r in recs.values()]),
        ),
        worst_residual_fp32=max(r["worst_residual"] for r in fp32),
        worst_x_diff_vs_fp64=max(r["x_diff_vs_fp64"]
                                 for r in recs.values()),
        fallback_rate=float(np.mean([r["fallback_rate"] for r in fp32])),
    )


def suite(quick=False, large=False):
    if quick:
        return [("circuit_150", CSR.from_scipy(matrices.circuit_like(150, 1)
                                               .tocsr()))]
    mats = [
        ("circuit_200", CSR.from_scipy(matrices.circuit_like(200, 1).tocsr())),
        ("fem2d_12", CSR.from_scipy(matrices.fem2d(12, 12, 4).tocsr())),
        ("unsym_150", CSR.from_scipy(matrices.unsym_random(150, 0.02, 8)
                                     .tocsr())),
    ]
    if large:
        mats += [(name, CSR.from_scipy(fn().tocsr()))
                 for name, fn in matrices.large_suite()]
    return mats


def bench_devices_sweep(name, Ac, k, n_devices, reps=5):
    """Batched refactor+solve throughput vs device count: the same matrix,
    K value sets, on a 1-D solver mesh over d = 1, 2, … devices.  Every
    mesh size runs the identical per-system program (parity is tested in
    tests/test_sharding.py); this measures only throughput."""
    import jax.numpy as jnp

    from repro.core import HyluOptions
    from repro.core.api import factor_batched, solve_batched

    rng = np.random.default_rng(0)
    vb = _value_drift(Ac.data, k, rng)
    bb = rng.normal(size=(k, Ac.n))
    counts = sorted({1, n_devices} | {d for d in (2, 4, 8, 16, 32, 64)
                                      if d < n_devices})
    out = dict(matrix=name, n=Ac.n, nnz=Ac.nnz, k=k, counts={})

    def _best(fn):
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts)

    vdev = jnp.asarray(vb)          # committed device buffer: staging cost
    #                                 excluded, like the single-device rows
    for d in counts:
        an = analyze(Ac, HyluOptions(mesh=d))
        t0 = time.perf_counter()
        bst = factor_batched(an, Ac, vdev)
        solve_batched(bst, bb)
        compile_s = time.perf_counter() - t0
        refac_s = _best(lambda: factor_batched(an, Ac, vdev))
        bst = factor_batched(an, Ac, vdev)
        solve_s = _best(lambda: solve_batched(bst, bb))
        rec = dict(devices=d, compile_s=compile_s,
                   refac_batched_s=refac_s, solve_fused_s=solve_s,
                   refac_systems_per_s=k / refac_s,
                   end2end_systems_per_s=k / (refac_s + solve_s))
        out["counts"][str(d)] = rec
        base = out["counts"]["1"]
        rec["speedup_refac_vs_1dev"] = (base["refac_batched_s"]
                                        / rec["refac_batched_s"])
        print(f"[devices] {name:14s} d={d:2d} "
              f"refac={refac_s*1e3:7.1f}ms "
              f"({rec['refac_systems_per_s']:8.0f} sys/s, "
              f"{rec['speedup_refac_vs_1dev']:.2f}x vs 1dev) "
              f"solve={solve_s*1e3:6.1f}ms compile={compile_s:4.1f}s",
              flush=True)
    return out


def compile_table(records) -> str:
    """Compile-vs-run table: the bucketed trace's headline numbers."""
    lines = [f"{'matrix':14s} {'n':>6s} {'compile_scalar':>15s} "
             f"{'compile_batched':>16s} {'refac_batched':>14s} "
             f"{'compile/run':>12s}"]
    for name, r in records.items():
        ratio = r["compile_batched_s"] / max(r["refac_jax_batched_s"], 1e-12)
        lines.append(f"{name:14s} {r['n']:6d} {r['compile_scalar_s']:13.2f}s "
                     f"{r['compile_batched_s']:14.2f}s "
                     f"{r['refac_jax_batched_s']*1e3:12.1f}ms "
                     f"{ratio:11.0f}x")
    return "\n".join(lines)


def bench_repeated(k=32, quick=False, large=False,
                   out_path="BENCH_repeated.json", jax_cache=None,
                   jax_cache_warm=False, devices=None, serving=True,
                   large_smoke=False, large_only=False, large_k=4,
                   amalg_tol=0.2, mixed_only=False,
                   serving_async_only=False):
    if serving_async_only:
        # the CI serving-chaos lane: just the async-server load-generator
        # section.  Merge into an existing results file instead of
        # clobbering the other sections, so the committed trajectory keeps
        # its full shape when only this lane reruns.
        out = {}
        if os.path.exists(out_path):
            try:
                with open(out_path) as f:
                    out = json.load(f)
            except (OSError, ValueError):
                out = {}
        out["serving_async"] = bench_serving_async(
            n_requests=80 if quick else 520,
            fault_rate=0.15)
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"results → {out_path}")
        return out
    if mixed_only:
        # the CI mixed-precision smoke: just the fp32-vs-fp64 section
        out = dict(k=k, jax_compilation_cache=jax_cache or None,
                   jax_cache_warm=bool(jax_cache_warm),
                   mixed_precision=bench_mixed_precision(k=k, quick=quick))
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"results → {out_path}")
        return out
    if large_only:
        # the CI scale lane: just the corpus section, skipping the main
        # suite entirely (the scale job budget is the corpus' budget)
        out = dict(k=k, jax_compilation_cache=jax_cache or None,
                   jax_cache_warm=bool(jax_cache_warm),
                   large=bench_corpus(k=large_k, smoke=large_smoke,
                                      amalg_fill_tol=amalg_tol))
        with open(out_path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"results → {out_path}")
        return out
    records = {}
    analyze_records = {}
    mats = suite(quick=quick, large=large)
    for name, Ac in mats:
        analyze_records[name] = bench_analyze_matrix(name, Ac)
        t0 = time.time()
        records[name] = bench_matrix(name, Ac, k)
        r = records[name]
        print(f"[repeated] {name:14s} n={r['n']:5d} mode={r['mode']:8s} "
              f"compile={r['compile_scalar_s']:5.1f}/"
              f"{r['compile_batched_s']:5.1f}s "
              f"refac ref={r['refac_ref_loop_s']*1e3:7.1f}ms "
              f"jit={r['refac_jax_jit_s']*1e3:7.1f}ms "
              f"batched={r['refac_jax_batched_s']*1e3:7.1f}ms "
              f"({r['speedup_refac_jax_batched']:.1f}x) "
              f"solve host={r['solve_hostloop_s']*1e3:6.1f}ms "
              f"fused={r['solve_fused_s']*1e3:6.1f}ms "
              f"({r['speedup_solve_fused']:.1f}x; refined "
              f"{r['speedup_solve_refined_fused']:.1f}x) "
              f"[{time.time()-t0:.0f}s]", flush=True)

    summary = {
        "refactor_jit": _geomean(
            [r["speedup_refac_jax_jit"] for r in records.values()]),
        "refactor_batched": _geomean(
            [r["speedup_refac_jax_batched"] for r in records.values()]),
        "end2end_jit": _geomean(
            [r["speedup_end2end_jax_jit"] for r in records.values()]),
        "end2end_batched": _geomean(
            [r["speedup_end2end_jax_batched"] for r in records.values()]),
        "solve_fused": _geomean(
            [r["speedup_solve_fused"] for r in records.values()]),
        "solve_refined_fused": _geomean(
            [r["speedup_solve_refined_fused"] for r in records.values()]),
        # absolute one-time costs (seconds), tracked so trace-size blowups
        # show up in the perf trajectory as hard numbers
        "compile_scalar_s": _geomean(
            [r["compile_scalar_s"] for r in records.values()]),
        "compile_batched_s": _geomean(
            [r["compile_batched_s"] for r in records.values()]),
    }
    # label whether compile numbers could have hit a warm persistent cache
    # — only cold (jax_cache disabled/fresh) numbers are trajectory-grade
    out = dict(k=k, jax_compilation_cache=jax_cache or None,
               jax_cache_warm=bool(jax_cache_warm),
               matrices=records, geomean_speedup_over_ref_loop=summary,
               analyze=analyze_records)
    # mixed precision: fp32-factor + fp64-refine vs pure fp64 (refactor
    # speedup, halved factor-panel bytes, fallback rate)
    out["mixed_precision"] = bench_mixed_precision(k=k, quick=quick)
    if serving:
        # mixed-pattern serving throughput (smaller request volume on
        # --quick so the CI bench job still records the section)
        out["serving"] = bench_serving(
            k_per_pattern=2 if quick else 8, reps=1 if quick else 2)
        # async continuous-batching server under the fault-injection load
        # generator (>=500-request stream on full runs)
        out["serving_async"] = bench_serving_async(
            n_requests=80 if quick else 520, fault_rate=0.15)
    if devices and devices > 1:
        # multi-device sweep on the first suite matrix (throughput vs
        # device count; bit-exact parity is the test suite's job)
        name0, Ac0 = mats[0]
        out["devices_sweep"] = bench_devices_sweep(name0, Ac0, k, devices)
    if large:
        # the scale trajectory: the SuiteSparse-class corpus at n>=10^4
        # with amalgamation on — runtime + peak-memory + pad-waste per
        # matrix, so scale regressions surface like speed regressions
        out["large"] = bench_corpus(k=large_k, smoke=large_smoke,
                                    amalg_fill_tol=amalg_tol)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    table = compile_table(records)
    table_path = out_path.rsplit(".", 1)[0] + "_compile_table.txt"
    with open(table_path, "w") as f:
        f.write(table + "\n")
    print("\ncompile-vs-run (one-time cost amortized over the sequence):")
    print(table)
    print(f"\ngeomean speedups over looped-ref (K={k}): "
          + "  ".join(f"{n}={v:.2f}{'' if n.endswith('_s') else 'x'}"
                      for n, v in summary.items()))
    print(f"results → {out_path}  compile table → {table_path}")
    bad = [name for name, r in records.items() if not r["solve_fused_ok"]]
    if bad:
        raise AssertionError(
            "no-refine fused solve slower than host loop on: "
            + ", ".join(bad) + " (results were still written)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--large", action="store_true",
                    help="add the circuit_2000-scale matrices AND run the "
                         "SuiteSparse-class corpus lane (the `large` "
                         "section: runtime + peak memory + pad waste at "
                         "n>=10^4, amalgamation on)")
    ap.add_argument("--large-smoke", action="store_true",
                    help="restrict the corpus lane to the CI scale-smoke "
                         "subset (one circuit-class + one FEM-class "
                         "matrix at n>=10^4)")
    ap.add_argument("--large-only", action="store_true",
                    help="run ONLY the corpus lane (the CI scale job), "
                         "skipping the main repeated-solve suite")
    ap.add_argument("--large-k", type=int, default=4,
                    help="system-batch size for the corpus lane's batched "
                         "refactor (smaller than --k: n>=10^4 systems)")
    ap.add_argument("--serving-async", action="store_true",
                    help="run ONLY the serving_async section (the CI "
                         "serving-chaos lane): the async continuous-"
                         "batching server under the fault-injection load "
                         "generator — req/s, p50/p99 latency, deadline-"
                         "miss and reject rates, merged into the "
                         "serving_async section of the results JSON")
    ap.add_argument("--mixed-only", action="store_true",
                    help="run ONLY the mixed_precision section (the CI "
                         "mixed-precision smoke): fp32-factor+fp64-refine "
                         "vs fp64 refactor/solve times, factor-panel "
                         "bytes, residual parity and fp64-fallback rate")
    ap.add_argument("--amalg-tol", type=float, default=0.2,
                    help="amalgamation fill tolerance for the corpus lane "
                         "(HyluOptions.amalg_fill_tol)")
    ap.add_argument("--out", default="BENCH_repeated.json")
    ap.add_argument("--jax-cache", default=None, metavar="DIR",
                    help="persistent JAX compilation cache dir "
                         "('' disables; default $JAX_COMPILATION_CACHE_DIR "
                         "or .jax_cache)")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="also sweep the sharded batched path over "
                         "1..N (virtual CPU) devices -> devices_sweep "
                         "section of the JSON")
    ap.add_argument("--no-serving", action="store_true",
                    help="skip the mixed-pattern SolverService section")
    args = ap.parse_args(argv)

    if args.devices and args.devices > 1:
        # must happen before anything touches jax devices in this process
        from repro.launch.mesh import ensure_virtual_cpu_devices
        ensure_virtual_cpu_devices(args.devices)

    from ._jax_cache import enable_jax_compilation_cache
    cache = enable_jax_compilation_cache(args.jax_cache)
    # pre-run state: a populated cache dir means the recorded compile
    # numbers may be warm-cache hits, not trajectory-grade cold compiles
    warm = bool(cache) and os.path.isdir(cache) and bool(os.listdir(cache))
    if cache:
        print(f"[jax] persistent compilation cache at {cache} "
              f"({'warm' if warm else 'cold'})")
    bench_repeated(k=args.k, quick=args.quick, large=args.large,
                   out_path=args.out, jax_cache=cache, jax_cache_warm=warm,
                   devices=args.devices, serving=not args.no_serving,
                   large_smoke=args.large_smoke, large_only=args.large_only,
                   large_k=args.large_k, amalg_tol=args.amalg_tol,
                   mixed_only=args.mixed_only,
                   serving_async_only=args.serving_async)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
