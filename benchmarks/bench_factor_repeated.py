"""Repeated-solve benchmark (paper Fig 8 scenario + the batched JAX engine).

One analysis, K refactorizations (+ solves) of the same sparsity pattern
with drifting values — the circuit-simulation workload HYLU's headline
2.90× repeated-factorization speedup comes from.  Three engines:

  looped-ref   K × ref_engine.factor in a Python loop (numpy reference)
  jitted-jax   K × pre-compiled XLA refactor calls (engine="jax")
  batched-jax  one vmapped XLA program for all K (factor_batched)

plus a solve-phase section comparing the fused on-device batched solve
(substitution + CSR residual matvec + the whole refinement loop as ONE
XLA program, `solve_batched`) against the pre-fusion host-loop baseline
(`api._solve_batched_hostloop`: one host round-trip per refinement
iteration).

Compile time is reported separately: it is part of the one-time analysis
cost, amortized over the thousands of steps of a transient run.

Writes BENCH_repeated.json (per-matrix timings + geomean speedups over
looped-ref) so successive PRs have a perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_factor_repeated [--k 32] [--quick]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import CSR, analyze, factor, refactor, solve
from repro.core.api import (factor_batched, solve_batched,
                            _solve_batched_hostloop, jax_repeated_engine)
from repro.core.ref_engine import factor_value_loop

from . import matrices


def _geomean(xs):
    xs = [x for x in xs if x and np.isfinite(x) and x > 0]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


def _value_drift(data, k, rng):
    """K value sets with the mild drift of Newton/transient sequences."""
    return data[None, :] * rng.uniform(0.9, 1.1, (k, len(data)))


def bench_matrix(name, Ac, k):
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    an = analyze(Ac)
    vb = _value_drift(Ac.data, k, rng)
    bb = rng.normal(size=(k, Ac.n))
    mats = [CSR(Ac.n, Ac.indptr, Ac.indices, vb[i]) for i in range(k)]
    rec = dict(n=Ac.n, nnz=Ac.nnz, mode=an.choice.mode, k=k)

    # ---- looped-ref: numeric refactorization only, then end-to-end --------
    mb = vb[:, an.src_map] * an.scale_map
    t0 = time.perf_counter()
    factor_value_loop(an.plan, an.m_pattern, mb,
                      perturb_eps=an.opts.perturb_eps)
    rec["refac_ref_loop_s"] = time.perf_counter() - t0

    st = factor(an, Ac, engine="ref")
    t0 = time.perf_counter()
    for i in range(k):
        st_i = refactor(st, mats[i])
        solve(st_i, bb[i])
    rec["end2end_ref_loop_s"] = time.perf_counter() - t0

    # ---- jitted-jax: compile once, K scalar pre-compiled calls ------------
    eng = jax_repeated_engine(an)
    t0 = time.perf_counter()
    st_j = factor(an, Ac, engine="jax")          # triggers refactor compile
    solve(st_j, bb[0])                           # triggers apply compile
    rec["compile_scalar_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(k):
        jf = eng.refactor(jnp.asarray(vb[i]))
    jax.block_until_ready(jf.vals)
    rec["refac_jax_jit_s"] = time.perf_counter() - t0

    t0 = time.perf_counter()
    for i in range(k):
        st_i = refactor(st_j, mats[i])
        solve(st_i, bb[i])
    rec["end2end_jax_jit_s"] = time.perf_counter() - t0

    # ---- batched-jax: one vmapped XLA program for all K -------------------
    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)             # includes vmap compile
    x, info = solve_batched(bst, bb)
    rec["compile_batched_s"] = time.perf_counter() - t0
    assert float(info["residual"].max()) < 1e-8, (name, info["residual"].max())

    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)
    rec["refac_jax_batched_s"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    rec["end2end_jax_batched_s"] = time.perf_counter() - t0

    # ---- solve phase: fused on-device refinement vs the host-loop baseline
    # (device substitution + numpy residual matvec + Python refine loop) ----
    reps = 5
    _solve_batched_hostloop(bst, bb)             # warm the scalar apply path
    t0 = time.perf_counter()
    for _ in range(reps):
        _solve_batched_hostloop(bst, bb)
    rec["solve_hostloop_s"] = (time.perf_counter() - t0) / reps
    solve_batched(bst, bb)                       # fused program is compiled
    t0 = time.perf_counter()
    for _ in range(reps):
        x, info = solve_batched(bst, bb)
    rec["solve_fused_s"] = (time.perf_counter() - t0) / reps
    rec["solve_n_refine"] = int(info["n_refine"])
    rec["speedup_solve_fused"] = (rec["solve_hostloop_s"]
                                  / rec["solve_fused_s"])

    # refinement-engaged: tol=0 forces the loop to iterate until it stalls,
    # so the per-iteration host round-trip of the baseline is actually on
    # the clock (tol is a dynamic arg — no recompile)
    tol_saved = an.opts.refine_tol
    an.opts.refine_tol = 0.0
    try:
        _solve_batched_hostloop(bst, bb, refine=True)
        t0 = time.perf_counter()
        for _ in range(reps):
            _, info_h = _solve_batched_hostloop(bst, bb, refine=True)
        rec["solve_refined_hostloop_s"] = (time.perf_counter() - t0) / reps
        solve_batched(bst, bb, refine=True)
        t0 = time.perf_counter()
        for _ in range(reps):
            _, info_f = solve_batched(bst, bb, refine=True)
        rec["solve_refined_fused_s"] = (time.perf_counter() - t0) / reps
        rec["solve_refined_n_iter"] = int(info_f["n_refine"])
        rec["speedup_solve_refined_fused"] = (
            rec["solve_refined_hostloop_s"] / rec["solve_refined_fused_s"])
    finally:
        an.opts.refine_tol = tol_saved

    for which in ("jax_jit", "jax_batched"):
        rec[f"speedup_refac_{which}"] = (rec["refac_ref_loop_s"]
                                         / rec[f"refac_{which}_s"])
        rec[f"speedup_end2end_{which}"] = (rec["end2end_ref_loop_s"]
                                           / rec[f"end2end_{which}_s"])
    return rec


def suite(quick=False):
    if quick:
        return [("circuit_150", CSR.from_scipy(matrices.circuit_like(150, 1)
                                               .tocsr()))]
    return [
        ("circuit_200", CSR.from_scipy(matrices.circuit_like(200, 1).tocsr())),
        ("fem2d_12", CSR.from_scipy(matrices.fem2d(12, 12, 4).tocsr())),
        ("unsym_150", CSR.from_scipy(matrices.unsym_random(150, 0.02, 8)
                                     .tocsr())),
    ]


def bench_repeated(k=32, quick=False, out_path="BENCH_repeated.json"):
    records = {}
    for name, Ac in suite(quick=quick):
        t0 = time.time()
        records[name] = bench_matrix(name, Ac, k)
        r = records[name]
        print(f"[repeated] {name:14s} n={r['n']:5d} mode={r['mode']:8s} "
              f"refac ref={r['refac_ref_loop_s']*1e3:7.1f}ms "
              f"jit={r['refac_jax_jit_s']*1e3:7.1f}ms "
              f"batched={r['refac_jax_batched_s']*1e3:7.1f}ms "
              f"({r['speedup_refac_jax_batched']:.1f}x) "
              f"solve host={r['solve_hostloop_s']*1e3:6.1f}ms "
              f"fused={r['solve_fused_s']*1e3:6.1f}ms "
              f"({r['speedup_solve_fused']:.1f}x; refined "
              f"{r['speedup_solve_refined_fused']:.1f}x) "
              f"[{time.time()-t0:.0f}s]", flush=True)

    summary = {
        "refactor_jit": _geomean(
            [r["speedup_refac_jax_jit"] for r in records.values()]),
        "refactor_batched": _geomean(
            [r["speedup_refac_jax_batched"] for r in records.values()]),
        "end2end_jit": _geomean(
            [r["speedup_end2end_jax_jit"] for r in records.values()]),
        "end2end_batched": _geomean(
            [r["speedup_end2end_jax_batched"] for r in records.values()]),
        "solve_fused": _geomean(
            [r["speedup_solve_fused"] for r in records.values()]),
        "solve_refined_fused": _geomean(
            [r["speedup_solve_refined_fused"] for r in records.values()]),
    }
    out = dict(k=k, matrices=records, geomean_speedup_over_ref_loop=summary)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\ngeomean speedups over looped-ref (K={k}): "
          + "  ".join(f"{n}={v:.2f}x" for n, v in summary.items()))
    print(f"results → {out_path}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_repeated.json")
    args = ap.parse_args(argv)
    bench_repeated(k=args.k, quick=args.quick, out_path=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
