"""Thin per-figure wrapper (DESIGN.md experiment index) → benchmarks.run."""
from .run import main as _main


def main(argv=None):
    return _main(["--figures", "7"] + (argv or []))


if __name__ == "__main__":
    raise SystemExit(main())
