"""Benchmark harness — one experiment per paper table/figure.

  Fig 4   preprocessing time, one-time solve
  Fig 5   numerical factorization, one-time
  Fig 6   forward/backward substitution, one-time
  Fig 7   total one-time solve
  Fig 8   numerical (re)factorization, repeated solve
  Fig 9   substitution, repeated solve
  Fig 10  factorization+substitution total, repeated solve
  Fig 11  residual ‖Ax−b‖₁/‖b‖₁

Solvers:
  hylu          — hybrid kernels + smart selection (the paper)
  klu_like      — row-row only internal baseline (KLU design point)
  pardiso_like  — supernodal-only internal baseline (PARDISO design point)
  superlu       — scipy.sparse.linalg.splu (SuperLU; the paper's ref [2]),
                  external C-compiled reference

The paper's headline claims are geomean speedups of hylu over the
level-3-BLAS supernodal design point (2.36× one-time / 2.90× repeated
factorization) and stability across sparsity classes; we report the same
geomeans over the internal baselines (identical engine, only the kernel
strategy differs — a controlled comparison) plus SuperLU absolute numbers
for external reference.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--figures 5,8,11]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import scipy.sparse.linalg as spla

from repro.core.api import analyze, factor, refactor, solve
from repro.core import baselines as B
from repro.core.matrix import CSR

from . import matrices

SOLVERS = ["hylu", "klu_like", "pardiso_like", "superlu"]


def geomean(xs):
    xs = [x for x in xs if x and np.isfinite(x) and x > 0]
    return float(np.exp(np.mean(np.log(xs)))) if xs else float("nan")


def bench_matrix(name, Ac, a_sp):
    """Run every solver on one matrix; return timing/accuracy records."""
    rng = np.random.default_rng(0)
    b = rng.normal(size=Ac.n)
    out = {}
    opts = {"hylu": B.hylu_options(), "klu_like": B.klu_like_options(),
            "pardiso_like": B.pardiso_like_options()}
    an0 = None
    for sname in ("hylu", "klu_like", "pardiso_like"):
        t0 = time.perf_counter()
        # matching+ordering are mode-independent: computed once (hylu run),
        # then reused — their cost is included in every mode's `pre` time
        # via t_shared so per-solver preprocessing stays honest.
        an = analyze(Ac, opts[sname], reuse=an0)
        t_pre = time.perf_counter() - t0
        if an0 is None:
            an0 = an
            t_shared = an.timings["matching"] + an.timings["ordering"]
        else:
            t_pre += t_shared
        # fill-blowup guard: when a forced-supernodal plan predicts >25× the
        # hybrid plan's padded flops (the ASIC/circuit5M phenomenon the
        # paper reports for PARDISO), record the ratio instead of burning
        # hours in the reference engine.
        if (sname == "pardiso_like"
                and an.plan.padded_flops > 25 * max(an0.plan.padded_flops, 1)):
            ratio = an.plan.padded_flops / max(an0.plan.padded_flops, 1)
            out[sname] = dict(pre=t_pre, fac=None, sub=None, refac=None,
                              sub2=None, resid=None,
                              mode=f"fill-blowup({ratio:.0f}x flops)",
                              n_perturb=0, flops_ratio_vs_hylu=ratio)
            continue
        t0 = time.perf_counter()
        st = factor(an, Ac)
        t_fac = time.perf_counter() - t0
        x, info = solve(st, b)
        t_sub = info["solve_time"]
        # repeated solve: new values, same pattern
        a2 = Ac.data * rng.uniform(0.9, 1.1, Ac.nnz)
        A2 = CSR(Ac.n, Ac.indptr, Ac.indices, a2)
        t0 = time.perf_counter()
        st2 = refactor(st, A2)
        t_refac = time.perf_counter() - t0
        x2, info2 = solve(st2, b)
        out[sname] = dict(pre=t_pre, fac=t_fac, sub=t_sub, refac=t_refac,
                          sub2=info2["solve_time"], resid=info["residual"],
                          mode=an.choice.mode, n_perturb=info["n_perturb"])
    # SuperLU external reference
    t0 = time.perf_counter()
    lu = spla.splu(a_sp.tocsc())
    t_fac = time.perf_counter() - t0
    t0 = time.perf_counter()
    x = lu.solve(b)
    t_sub = time.perf_counter() - t0
    resid = float(np.abs(a_sp @ x - b).sum() / np.abs(b).sum())
    t0 = time.perf_counter()
    spla.splu(a_sp.tocsc())              # SuperLU exposes no refactor API
    t_refac = time.perf_counter() - t0
    out["superlu"] = dict(pre=0.0, fac=t_fac, sub=t_sub, refac=t_refac,
                          sub2=t_sub, resid=resid, mode="superlu",
                          n_perturb=0)
    return out


FIGS = {
    4: ("preprocessing (one-time)", lambda r: r["pre"]),
    5: ("numerical factorization (one-time)", lambda r: r["fac"]),
    6: ("substitution (one-time)", lambda r: r["sub"]),
    7: ("total one-time", lambda r: r["pre"] + r["fac"] + r["sub"]),
    8: ("factorization (repeated)", lambda r: r["refac"]),
    9: ("substitution (repeated)", lambda r: r["sub2"]),
    10: ("fac+sub total (repeated)", lambda r: r["refac"] + r["sub2"]),
    11: ("residual |Ax-b|1/|b|1", lambda r: r["resid"]),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--figures", default="4,5,6,7,8,9,10,11")
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--repeated-k", type=int, default=32,
                    help="K value sets for the repeated-solve engine bench")
    ap.add_argument("--no-repeated", action="store_true",
                    help="skip the jax/batched repeated-solve engine bench")
    ap.add_argument("--jax-cache", default=None, metavar="DIR",
                    help="persistent JAX compilation cache dir "
                         "('' disables; default $JAX_COMPILATION_CACHE_DIR "
                         "or .jax_cache)")
    args = ap.parse_args(argv)
    from ._jax_cache import enable_jax_compilation_cache
    cache = enable_jax_compilation_cache(args.jax_cache)
    if cache:
        print(f"[jax] persistent compilation cache at {cache}")
    figs = [int(f) for f in args.figures.split(",")]
    scale = 0.15 if args.quick else 0.35
    os.makedirs(args.out, exist_ok=True)

    records = {}
    t_all = time.time()
    for name_fn in matrices.suite(scale=scale):
        name, Ac, a_sp = matrices.load(name_fn)
        t0 = time.time()
        records[name] = bench_matrix(name, Ac, a_sp)
        records[name]["_meta"] = dict(n=Ac.n, nnz=Ac.nnz)
        print(f"[bench] {name:20s} n={Ac.n:7d} nnz={Ac.nnz:8d} "
              f"mode={records[name]['hylu']['mode']:10s} "
              f"({time.time()-t0:.1f}s)", flush=True)

    print(f"\nsuite done in {time.time()-t_all:.0f}s — "
          f"{len(records)} matrices\n")

    summary = {}
    for fig in figs:
        title, get = FIGS[fig]
        print(f"=== Fig {fig}: {title} ===")
        print(f"{'matrix':20s} " + " ".join(f"{s:>13s}" for s in SOLVERS))
        speed = {s: [] for s in SOLVERS}

        def safe_get(r):
            try:
                v = get(r)
                return v if v is not None else float("nan")
            except TypeError:
                return float("nan")

        for name, rec in records.items():
            row = [safe_get(rec[s]) for s in SOLVERS]
            print(f"{name:20s} " + " ".join(f"{v:13.4g}" for v in row))
            if fig != 11 and row[0] > 0:
                for s, v in zip(SOLVERS, row):
                    if np.isfinite(v):
                        speed[s].append(v / row[0])
        if fig != 11:
            gm = {s: geomean(speed[s]) for s in SOLVERS if s != "hylu"}
            print(f"{'geomean speedup of hylu':24s} " +
                  "  ".join(f"vs {s}: {v:.2f}x" for s, v in gm.items()))
            summary[f"fig{fig}"] = gm
        else:
            gm = {s: geomean([safe_get(rec[s]) for rec in records.values()])
                  for s in SOLVERS}
            print("geomean residuals:", {k: f"{v:.2e}" for k, v in gm.items()})
            summary["fig11"] = gm
        print()

    with open(os.path.join(args.out, "bench_results.json"), "w") as f:
        json.dump(dict(records=records, summary=summary), f, indent=1,
                  default=str)
    print(f"results → {args.out}/bench_results.json")

    # repeated-solve engine comparison (looped-ref vs jitted/batched jax) —
    # the machine-readable perf trajectory for the repeated-solve path
    if 8 in figs and not args.no_repeated:
        from .bench_factor_repeated import bench_repeated
        bench_repeated(k=args.repeated_k, quick=args.quick,
                       out_path=os.path.join(args.out, "BENCH_repeated.json"))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
