"""CI guard: scalar-refactor compile time on circuit_200 must stay under a
generous ceiling so O(nodes+edges) trace-size blowups can't silently
return (the pre-bucketed engine took 70+ s here; the level-bucketed trace
takes single-digit seconds).

Runs with the persistent compilation cache pointed at a throwaway
directory — the measurement must be a *cold* compile.

    PYTHONPATH=src python -m benchmarks.compile_budget [--ceiling 120]
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ceiling", type=float, default=120.0,
                    help="hard compile-time ceiling in seconds")
    args = ap.parse_args(argv)

    import jax
    jax.config.update("jax_enable_x64", True)
    # fresh throwaway cache dir: never reuse a warm cache for the guard
    jax.config.update("jax_compilation_cache_dir", tempfile.mkdtemp())

    from repro.core.matrix import CSR
    from repro.core.api import analyze, factor, solve

    from . import matrices

    a = CSR.from_scipy(matrices.circuit_like(200, 1).tocsr())
    an = analyze(a)
    b = np.random.default_rng(0).normal(size=a.n)
    t0 = time.perf_counter()
    st = factor(an, a, engine="jax")
    x, info = solve(st, b)
    elapsed = time.perf_counter() - t0
    ok = elapsed <= args.ceiling
    print(f"[compile-budget] circuit_200 scalar refactor+solve compile: "
          f"{elapsed:.1f}s (ceiling {args.ceiling:.0f}s) "
          f"residual={info['residual']:.1e} → {'OK' if ok else 'FAIL'}")
    if not ok:
        print("trace-size blowup: the factor/solve trace is no longer "
              "O(levels × buckets) — check jax_engine.make_factor_fn and "
              "structure.build_bucket_schedule", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
