"""Persistent JAX compilation cache for benchmark / CI runs.

Compilation is the dominant one-time cost of the repeated-solve engine;
enabling ``jax_compilation_cache_dir`` means repeat bench and CI runs on
an unchanged program skip it entirely.  Honest *cold* compile numbers
(the ones recorded in BENCH_repeated.json) are taken by pointing the
cache at a fresh directory or disabling it with ``--jax-cache ''``.
"""
from __future__ import annotations

import os


def enable_jax_compilation_cache(path: str | None = None):
    """Enable the persistent compilation cache; returns the directory used
    (or None when disabled with an empty path).

    Resolution order: explicit ``path`` → $JAX_COMPILATION_CACHE_DIR →
    ``.jax_cache`` in the working directory."""
    import jax

    cache_dir = path if path is not None else os.environ.get(
        "JAX_COMPILATION_CACHE_DIR", ".jax_cache")
    if not cache_dir:
        return None
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    # cache even fast compiles: the bench re-runs hundreds of small programs
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.1)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", 0)
    return cache_dir
