"""SuiteSparse-class corpus harness: real matrices when reachable,
statistics-matched synthetic stand-ins when not.

The paper's headline numbers (2.36-2.90x geomean over PARDISO) come from
37 real SuiteSparse matrices; our repeated-solve suite tops out at
synthetic n=2000.  This module is the bridge to that scale:

* a registry of real SuiteSparse matrices in the n=10^4-10^5 range
  (circuit / power-grid / FEM classes — the regimes HYLU routes between),
  downloaded from sparse.tamu.edu and cached under the shared artifact
  root (``$HYLU_CACHE_ROOT`` / ``<repo>/checkpoints`` — same resolution
  as the plan cache, so CI caches one directory);
* deterministic synthetic fallbacks per entry, built from
  :mod:`matrices`' class generators at matched size/density, so the
  ``--large`` bench lane runs the SAME corpus names online and offline —
  offline runs degrade to the stand-in, never skip silently;
* ``matrix_stats`` — the sparsity statistics the stand-ins are matched
  on (size, density, pattern-symmetry fraction, degree profile), recorded
  next to every bench record so a synthetic run is auditable against the
  real matrix it stands in for.

    PYTHONPATH=src:benchmarks python -c "import corpus; corpus.main()"

prints the corpus with per-entry stats and their source.
"""
from __future__ import annotations

import dataclasses
import io
import os
import tarfile
import urllib.request

import numpy as np
import scipy.sparse as sp

try:                                  # package context (python -m benchmarks.*)
    from .matrices import circuit_like, powergrid_like, fem2d, fem3d
except ImportError:                   # flat context (PYTHONPATH=benchmarks)
    from matrices import circuit_like, powergrid_like, fem2d, fem3d
from repro.core.matrix import CSR

SUITESPARSE_URL = "https://sparse.tamu.edu/MM/{group}/{name}.tar.gz"
DOWNLOAD_TIMEOUT_S = 60


def corpus_root(root: str | None = None) -> str:
    """Where downloaded matrices live: ``<cache root>/corpus`` under the
    same root the plan cache resolves (HYLU_CACHE_ROOT / repo
    checkpoints), so one CI cache path covers both artifact stores."""
    if root is None:
        from repro.core.plan_cache import default_cache_root
        root = default_cache_root()
    return os.path.join(root, "corpus")


@dataclasses.dataclass(frozen=True)
class CorpusEntry:
    """One corpus matrix: a real SuiteSparse (group, name) target plus the
    deterministic synthetic stand-in used when the download is
    unreachable.  ``klass`` is the sparsity class the scale lane slices
    by; ``analyze_only`` marks entries past the compile budget (the bench
    records analyze+plan statistics but skips the XLA build)."""
    name: str
    klass: str                       # circuit | powergrid | fem
    gen: object                      # () -> scipy CSR, deterministic
    suitesparse: tuple | None = None # (group, name) on sparse.tamu.edu
    analyze_only: bool = False


def _entries() -> list:
    return [
        # circuit class (paper's headline regime: rowrow routing, long
        # narrow level tails — the amalgamation stress case)
        CorpusEntry("memplus", "circuit",
                    lambda: circuit_like(17758, seed=910),
                    suitesparse=("Hamm", "memplus")),
        CorpusEntry("circuit_3", "circuit",
                    lambda: circuit_like(12127, seed=911),
                    suitesparse=("Bomhof", "circuit_3")),
        CorpusEntry("circuit_10k", "circuit",
                    lambda: circuit_like(10000, seed=912)),
        CorpusEntry("circuit_100k", "circuit",
                    lambda: circuit_like(100000, seed=913),
                    analyze_only=True),
        # power-grid class
        CorpusEntry("bcspwr10", "powergrid",
                    lambda: powergrid_like(72, 74, seed=920),
                    suitesparse=("HB", "bcspwr10")),
        CorpusEntry("powergrid_11k", "powergrid",
                    lambda: powergrid_like(100, 110, seed=921)),
        # FEM class (hybrid/supernodal routing; wide panels)
        CorpusEntry("fem2d_10k", "fem",
                    lambda: fem2d(100, 100, seed=930)),
        CorpusEntry("fem3d_11k", "fem",
                    lambda: fem3d(22, 22, 22, seed=931)),
    ]


def corpus() -> list:
    """The full ``--large`` corpus (the nightly lane)."""
    return _entries()


def smoke_corpus() -> list:
    """The CI scale-smoke subset: one circuit-class and one FEM-class
    matrix at n >= 10^4, both synthetic-deterministic so the smoke lane
    never depends on network reachability."""
    by_name = {e.name: e for e in _entries()}
    return [by_name["circuit_10k"], by_name["fem2d_10k"]]


def matrix_stats(a: sp.spmatrix) -> dict:
    """The sparsity statistics synthetic stand-ins are matched on."""
    a = a.tocsr()
    n = a.shape[0]
    nnz = a.nnz
    pattern = a.copy()
    pattern.data = np.ones_like(pattern.data)
    both = pattern.multiply(pattern.T)
    deg = np.diff(a.indptr)
    return dict(
        n=int(n),
        nnz=int(nnz),
        density=float(nnz) / float(n) ** 2,
        avg_degree=float(nnz) / float(n),
        max_degree=int(deg.max()) if n else 0,
        symmetry_frac=float(both.nnz) / max(nnz, 1),
    )


def _extract_mtx(tar_bytes: bytes, name: str) -> sp.spmatrix | None:
    """The main ``<name>/<name>.mtx`` member of a SuiteSparse tarball
    (ignoring the ``_b``/``_x`` auxiliary vectors some entries carry)."""
    import scipy.io

    with tarfile.open(fileobj=io.BytesIO(tar_bytes), mode="r:gz") as tf:
        for member in tf.getmembers():
            base = os.path.basename(member.name)
            if base == f"{name}.mtx":
                f = tf.extractfile(member)
                if f is not None:
                    return sp.csr_matrix(scipy.io.mmread(f))
    return None


def fetch_suitesparse(group: str, name: str, root: str | None = None,
                      allow_download: bool = True) -> sp.spmatrix | None:
    """``<root>/corpus/<group>_<name>.npz`` if cached, else download from
    sparse.tamu.edu (when allowed) and cache.  Returns None — never
    raises — when the matrix is unreachable: callers fall back to the
    synthetic stand-in, so offline runs degrade instead of failing."""
    cdir = corpus_root(root)
    path = os.path.join(cdir, f"{group}_{name}.npz")
    if os.path.exists(path):
        try:
            return sp.load_npz(path)
        except (OSError, ValueError):
            pass                      # corrupt cache entry: re-download
    if not allow_download or os.environ.get("HYLU_CORPUS_OFFLINE"):
        return None
    url = SUITESPARSE_URL.format(group=group, name=name)
    try:
        with urllib.request.urlopen(url, timeout=DOWNLOAD_TIMEOUT_S) as r:
            a = _extract_mtx(r.read(), name)
    except Exception:                 # URLError/timeout/bad archive: offline
        return None
    if a is None:
        return None
    os.makedirs(cdir, exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    try:
        sp.save_npz(tmp, sp.csr_matrix(a))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return a


def load_entry(entry: CorpusEntry, root: str | None = None,
               allow_download: bool = True) -> tuple:
    """(CSR, scipy CSR, meta) for one corpus entry — the real SuiteSparse
    matrix when reachable, its synthetic stand-in otherwise.  ``meta``
    records which one ran (``source``) plus :func:`matrix_stats`, so
    bench records are auditable."""
    a = None
    source = "synthetic"
    if entry.suitesparse is not None:
        a = fetch_suitesparse(*entry.suitesparse, root=root,
                              allow_download=allow_download)
        if a is not None:
            source = "suitesparse"
    if a is None:
        a = entry.gen()
    a = sp.csr_matrix(a)
    if a.shape[0] != a.shape[1]:
        raise ValueError(f"corpus entry {entry.name}: matrix is "
                         f"{a.shape[0]}x{a.shape[1]}, expected square")
    a.sort_indices()
    meta = dict(name=entry.name, klass=entry.klass, source=source,
                analyze_only=entry.analyze_only, **matrix_stats(a))
    return CSR.from_scipy(a), a, meta


def main() -> None:
    for e in corpus():
        _, _, meta = load_entry(e, allow_download=False)
        print({k: (round(v, 6) if isinstance(v, float) else v)
               for k, v in meta.items()})


if __name__ == "__main__":
    main()
