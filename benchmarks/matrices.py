"""Synthetic benchmark-matrix suite mirroring the paper's 37 SuiteSparse
classes (SuiteSparse itself is not downloadable offline).

Classes and the real matrices they stand in for:
  circuit_*    — extremely sparse, irregular (ASIC_680k, circuit5M, rajat*)
  asic_*       — circuit + a few dense power-net rows/cols: the class where
                 supernodal solvers generate huge fill (paper §3.1 calls out
                 ASIC_680k/ASIC_680ks/circuit5M explicitly)
  powergrid_*  — grid Laplacian + long-range ties (TSOPF, case39 family)
  fem2d_*      — 5-point Poisson stencils (thermal*, apache*)
  fem3d_*      — 7-point stencils (G3_circuit-ish, parabolic_fem)
  banded_*     — narrow band + random off-band (s3dkq4m2-ish)
  kkt_*        — saddle-point KKT blocks (nlpkkt80 stand-in; indefinite,
                 exercises static pivoting + perturbation)
  unsym_*      — general unsymmetric random (raefsky*, venkat*)

Sizes are scaled to a 1-core CPU budget; every generator is seeded and
deterministic. 37 matrices total, as in the paper.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from repro.core.matrix import CSR


def _laplacian_of_edges(n, rows, cols, vals, diag_jitter, rng):
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    a = a + a.T
    d = np.abs(a).sum(axis=1).A.ravel() + rng.uniform(0.1, 1.0, n) * diag_jitter
    return (sp.diags(d) - a).tocsr()


def circuit_like(n, seed, avg_deg=3.0, locality=16, long_frac=0.005):
    """Circuit netlists are LOCAL graphs (placed cells talk to neighbors,
    plus a few long wires) — uniform random graphs are expanders with no
    small separators and would misrepresent the class."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    rows = rng.integers(0, n, m)
    delta = rng.geometric(1.0 / locality, m)
    cols = np.clip(rows + rng.choice([-1, 1], m) * delta, 0, n - 1)
    ml = int(m * long_frac)                   # a few cross-chip wires
    rows = np.concatenate([rows, rng.integers(0, n, ml)])
    cols = np.concatenate([cols, rng.integers(0, n, ml)])
    vals = rng.uniform(0.1, 10.0, len(rows))  # conductances
    keep = rows != cols
    return _laplacian_of_edges(n, rows[keep], cols[keep], vals[keep], 1.0, rng)


def asic_like(n, seed, avg_deg=3.0, n_dense=4):
    rng = np.random.default_rng(seed)
    a = circuit_like(n, seed, avg_deg).tolil()
    # dense power-net rows/cols (the supernodal fill bomb)
    for i in rng.integers(0, n, n_dense):
        js = rng.integers(0, n, n // 20)
        a[i, js] = rng.uniform(0.01, 1.0, len(js))
        a[js, i] = rng.uniform(0.01, 1.0, len(js))
        a[i, i] = 100.0
    return a.tocsr()


def powergrid_like(nx, ny, seed, extra_frac=0.05):
    rng = np.random.default_rng(seed)
    n = nx * ny
    g = sp.lil_matrix((n, n))
    idx = lambda i, j: i * ny + j
    rows, cols, vals = [], [], []
    for i in range(nx):
        for j in range(ny):
            for di, dj in ((0, 1), (1, 0)):
                if i + di < nx and j + dj < ny:
                    rows.append(idx(i, j)); cols.append(idx(i + di, j + dj))
                    vals.append(rng.uniform(0.5, 5.0))
    m = int(n * extra_frac)
    rows += list(rng.integers(0, n, m)); cols += list(rng.integers(0, n, m))
    vals += list(rng.uniform(0.1, 2.0, m))
    rows, cols, vals = np.array(rows), np.array(cols), np.array(vals)
    keep = rows != cols
    return _laplacian_of_edges(n, rows[keep], cols[keep], vals[keep], 0.5, rng)


def fem2d(nx, ny, seed=0):
    rng = np.random.default_rng(seed)
    ex = np.ones(nx)
    ey = np.ones(ny)
    tx = sp.diags([-ex[:-1], 2 * ex, -ex[:-1]], [-1, 0, 1])
    ty = sp.diags([-ey[:-1], 2 * ey, -ey[:-1]], [-1, 0, 1])
    a = sp.kronsum(tx, ty).tocsr()
    a = a + sp.diags(rng.uniform(0.0, 0.1, a.shape[0]))
    return a


def fem3d(nx, ny, nz, seed=0):
    rng = np.random.default_rng(seed)
    def t(m):
        e = np.ones(m)
        return sp.diags([-e[:-1], 2 * e, -e[:-1]], [-1, 0, 1])
    a = sp.kronsum(sp.kronsum(t(nx), t(ny)), t(nz)).tocsr()
    return a + sp.diags(rng.uniform(0.0, 0.1, a.shape[0]))


def banded(n, bw, seed, fill=0.6):
    rng = np.random.default_rng(seed)
    diags = []
    offs = []
    for k in range(1, bw + 1):
        if rng.random() < fill:
            diags += [rng.normal(size=n - k), rng.normal(size=n - k)]
            offs += [k, -k]
    a = sp.diags(diags, offs, shape=(n, n))
    a = a + sp.diags(rng.uniform(2 * bw, 3 * bw, n))
    return a.tocsr()


def kkt(nh, nc, seed):
    rng = np.random.default_rng(seed)
    h = sp.random(nh, nh, density=4.0 / nh,
                  random_state=np.random.RandomState(seed))
    h = h + h.T + sp.diags(rng.uniform(1, 3, nh))
    a = sp.random(nc, nh, density=6.0 / nh,
                  random_state=np.random.RandomState(seed + 1))
    z = sp.coo_matrix((nc, nc))
    kkt_m = sp.bmat([[h, a.T], [a, z]], format="csr")
    # tiny regularization so the matrix is nonsingular but still exercises
    # matching + perturbation
    reg = sp.diags(np.concatenate([np.zeros(nh), -1e-4 * np.ones(nc)]))
    return (kkt_m + reg).tocsr()


def unsym_random(n, density, seed):
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="csr")
    return (a + sp.diags(rng.uniform(1, 2, n) * rng.choice([-1, 1], n))).tocsr()


def suite(scale=1.0):
    """The 37-matrix suite. scale shrinks sizes for --quick runs."""
    s = lambda v: max(int(v * scale), 64)
    mats = []
    # 8 circuit
    for i, n in enumerate([2000, 4000, 8000, 12000, 16000, 24000, 32000, 48000]):
        mats.append((f"circuit_{n//1000}k", lambda n=n, i=i: circuit_like(s(n), 100 + i)))
    # 4 asic-like (dense-row fill bombs)
    for i, n in enumerate([2000, 6000, 12000, 24000]):
        mats.append((f"asic_{n//1000}k", lambda n=n, i=i: asic_like(s(n), 200 + i)))
    # 5 powergrid
    for i, (nx, ny) in enumerate([(40, 50), (60, 70), (80, 90), (100, 110), (120, 140)]):
        mats.append((f"powergrid_{nx*ny//1000}k",
                     lambda nx=nx, ny=ny, i=i: powergrid_like(
                         max(int(nx * scale**0.5), 8),
                         max(int(ny * scale**0.5), 8), 300 + i)))
    # 6 fem2d
    for i, (nx, ny) in enumerate([(40, 40), (56, 56), (70, 70), (85, 85),
                                  (100, 100), (120, 120)]):
        mats.append((f"fem2d_{nx}x{ny}",
                     lambda nx=nx, ny=ny, i=i: fem2d(
                         max(int(nx * scale**0.5), 8),
                         max(int(ny * scale**0.5), 8), 400 + i)))
    # 4 fem3d
    for i, m in enumerate([10, 13, 16, 20]):
        mats.append((f"fem3d_{m}^3",
                     lambda m=m, i=i: fem3d(max(int(m * scale**0.34), 4),
                                            max(int(m * scale**0.34), 4),
                                            max(int(m * scale**0.34), 4),
                                            500 + i)))
    # 4 banded
    for i, (n, bw) in enumerate([(3000, 8), (6000, 12), (10000, 16), (16000, 24)]):
        mats.append((f"banded_{n//1000}k_bw{bw}",
                     lambda n=n, bw=bw, i=i: banded(s(n), bw, 600 + i)))
    # 3 kkt
    for i, (nh, nc) in enumerate([(1500, 500), (3000, 1000), (6000, 2000)]):
        mats.append((f"kkt_{(nh+nc)//1000}k",
                     lambda nh=nh, nc=nc, i=i: kkt(s(nh), s(nc), 700 + i)))
    # 3 unsym random
    for i, (n, d) in enumerate([(2000, 0.002), (5000, 0.001), (10000, 0.0006)]):
        mats.append((f"unsym_{n//1000}k",
                     lambda n=n, d=d, i=i: unsym_random(s(n), d, 800 + i)))
    assert len(mats) == 37
    return mats


def large_suite():
    """circuit_2000-scale generators (gated behind ``--large`` in the
    benchmarks): an order of magnitude past the historical repeated-solve
    suite, feasible only with the level-bucketed factor trace — the
    unrolled O(nodes+edges) trace does not compile at this size in any
    reasonable time."""
    return [
        ("circuit_2000", lambda: circuit_like(2000, 3)),
        ("banded_2000", lambda: banded(2000, 6, 5)),
    ]


def load(name_fn):
    name, fn = name_fn
    a = fn().tocsr()
    a.sort_indices()
    return name, CSR.from_scipy(a), a
