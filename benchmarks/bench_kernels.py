"""Pallas-kernel microbench: shape sweep, correctness-vs-oracle error and
interpret-mode wall time (CPU interpret times are NOT TPU performance —
they validate kernel semantics across the shape grid; TPU timing requires
real hardware).

    PYTHONPATH=src python -m benchmarks.bench_kernels
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

RNG = np.random.default_rng(0)


def timeit(fn, *args):
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, out)
    return time.perf_counter() - t0, out


def main():
    print(f"{'kernel':12s} {'shape':28s} {'us(interp)':>12s} {'max_err':>10s}")
    # GEMM update (sup-sup)
    from repro.kernels.supsup import ops as ss
    from repro.kernels.supsup.ref import gemm_update_ref
    for nr, k, m in [(64, 32, 128), (128, 64, 256), (128, 128, 512)]:
        c = jnp.asarray(RNG.normal(size=(nr, m)), jnp.float32)
        a = jnp.asarray(RNG.normal(size=(nr, k)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(k, m)), jnp.float32)
        dt, out = timeit(lambda c=c, a=a, b=b: ss.gemm(c, a, b))
        err = float(jnp.abs(out - gemm_update_ref(c, a, b)).max())
        print(f"{'supsup.gemm':12s} {f'{nr}x{k}x{m}':28s} {dt*1e6:12.0f} "
              f"{err:10.2e}")
    # TRSM
    from repro.kernels.trisolve import ops as tri
    from repro.kernels.trisolve.ref import trsm_upper_ref
    for nr, k in [(128, 32), (256, 64), (512, 128)]:
        u = jnp.asarray(np.triu(RNG.normal(size=(k, k))) + 3 * np.eye(k),
                        jnp.float32)
        x = jnp.asarray(RNG.normal(size=(nr, k)), jnp.float32)
        dt, y = timeit(lambda u=u, x=x: tri.trsm(u, x))
        err = float(jnp.abs(y - trsm_upper_ref(u, x)).max())
        print(f"{'trisolve':12s} {f'{nr}x{k}':28s} {dt*1e6:12.0f} {err:10.2e}")
    # flash attention
    from repro.kernels.flashattn.kernel import flash_attention
    from repro.kernels.flashattn.ref import attention_ref
    for b, h, t, d in [(1, 4, 256, 64), (2, 8, 512, 64)]:
        q = jnp.asarray(RNG.normal(size=(b, h, t, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(b, h, t, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(b, h, t, d)), jnp.float32)
        dt, o = timeit(lambda q=q, k=k, v=v: flash_attention(
            q, k, v, bq=128, bk=128))
        err = float(jnp.abs(o - attention_ref(q, k, v)).max())
        print(f"{'flashattn':12s} {f'{b}x{h}x{t}x{d}':28s} {dt*1e6:12.0f} "
              f"{err:10.2e}")
    # WKV
    from repro.kernels.wkv.ops import wkv_padded
    from repro.kernels.wkv.ref import wkv_ref
    for bh, t, hs in [(8, 512, 64), (16, 1024, 64)]:
        r = jnp.asarray(RNG.normal(size=(bh, t, hs)), jnp.float32)
        kk = jnp.asarray(RNG.normal(size=(bh, t, hs)) * 0.3, jnp.float32)
        v = jnp.asarray(RNG.normal(size=(bh, t, hs)), jnp.float32)
        w = jnp.asarray(RNG.uniform(0.8, 0.999, (bh, t, hs)), jnp.float32)
        u = jnp.asarray(RNG.normal(size=(bh, hs)) * 0.3, jnp.float32)
        dt, y = timeit(lambda: wkv_padded(r, kk, v, w, u, bt=256))
        yr, _ = wkv_ref(r, kk, v, w, u)
        err = float(jnp.abs(y - yr).max())
        print(f"{'wkv':12s} {f'{bh}x{t}x{hs}':28s} {dt*1e6:12.0f} {err:10.2e}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
