"""MC64 matching + scaling invariants (paper §2.1 static pivoting)."""
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.matrix import CSR
from repro.core.matching import max_weight_matching, apply_static_pivoting


def _random_nonsingular(rng, n, density):
    a = np.where(rng.random((n, n)) < density, rng.normal(size=(n, n)), 0.0)
    p = rng.permutation(n)
    a[np.arange(n), p] += rng.uniform(0.5, 2.0, n) * rng.choice([-1, 1], n)
    return a


@pytest.mark.parametrize("seed", range(5))
def test_matching_permutation_and_scaling(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 80))
    a = _random_nonsingular(rng, n, float(rng.uniform(0.05, 0.35)))
    m = max_weight_matching(CSR.from_dense(a))
    assert sorted(m.col_of_row.tolist()) == list(range(n))
    b, q = apply_static_pivoting(CSR.from_dense(a), m)
    bd = b.to_dense()
    assert np.all(np.abs(np.diag(bd)) > 1 - 1e-8)      # matched entries → ±1
    assert np.abs(bd).max() <= 1 + 1e-8                # off-diag bounded


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 40), st.floats(0.05, 0.5))
def test_matching_hypothesis(seed, n, density):
    rng = np.random.default_rng(seed)
    a = _random_nonsingular(rng, n, density)
    A = CSR.from_dense(a)
    m = max_weight_matching(A)
    # permutation validity
    assert sorted(m.col_of_row.tolist()) == list(range(n))
    # scaling bound: |Dr A Dc| <= 1 everywhere, == 1 on matched entries
    b, _ = apply_static_pivoting(A, m)
    bd = np.abs(b.to_dense())
    assert bd.max() <= 1 + 1e-8
    assert np.all(np.abs(np.diag(bd)) > 1 - 1e-8)
    # scales strictly positive and finite
    assert np.all(np.isfinite(m.row_scale)) and np.all(m.row_scale > 0)
    assert np.all(np.isfinite(m.col_scale)) and np.all(m.col_scale > 0)


def test_matching_improves_diagonal_product():
    """The matching maximizes the diagonal product; compare vs identity."""
    rng = np.random.default_rng(3)
    n = 30
    a = _random_nonsingular(rng, n, 0.3)
    A = CSR.from_dense(a)
    m = max_weight_matching(A)
    matched = np.abs(a[np.arange(n), m.col_of_row])
    assert np.all(matched > 0)  # matched entries structurally nonzero


def test_structurally_singular_handled():
    a = np.zeros((4, 4))
    a[0, 0] = a[1, 1] = a[2, 2] = 1.0   # row/col 3 empty
    m = max_weight_matching(CSR.from_dense(a))
    assert m.structurally_singular
    assert sorted(m.col_of_row.tolist()) == list(range(4))
