"""Parity suite for the level-bucketed factorization trace.

The bucketed schedule (O(levels × shape-buckets) trace) must produce the
same factors as the historical per-node/per-edge unrolled trace AND the
numpy reference engine — same panel values to 1e-10, same in-node pivot
choices (``inode_perm`` equality) and the same pivot-perturbation counts —
across the scenario matrix × kernel modes × execution paths (plain jit vs
Pallas interpret).  The two jax schedules differ only in floating-point
summation order of trailing updates, so agreement is at round-off.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.core import CSR, HyluOptions, analyze
from repro.core.api import _m_values, factor, solve
from repro.core.jax_engine import make_factor_fn
from repro.core import ref_engine

from tests.helpers import SCENARIOS, scenario_system

MODES = ["rowrow", "hybrid", "supernodal"]
PATHS = ["jit", "pallas-interpret"]
N = 30
ALL_CASES = [(s, m, p) for s in SCENARIOS for m in MODES for p in PATHS]


@pytest.fixture(scope="module")
def bucket_case(request):
    """One compiled (scenario, mode, path) combo: ref factors + bucketed
    and unrolled jax factors of the same preprocessed values."""
    scenario, mode, path = request.param
    Ac, a_sp, b, _ = scenario_system(scenario, n=N, seed=5)
    # bulk_min_width=2 so the bucketed path actually engages its bulk mode
    # (panel/edge buckets) at this test scale, not just the scan tail
    an = analyze(Ac, HyluOptions(force_mode=mode, bulk_min_width=2))
    m = _m_values(an, Ac)
    pallas = path == "pallas-interpret"
    f_ref = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    fb = jax.jit(make_factor_fn(an.plan, use_pallas=pallas,
                                bulk_min_width=2))(jnp.asarray(m.data))
    fu = jax.jit(make_factor_fn(an.plan, use_pallas=pallas,
                                schedule="unrolled"))(jnp.asarray(m.data))
    return scenario, mode, path, an, f_ref, fb, fu


@pytest.mark.parametrize("bucket_case", ALL_CASES, indirect=True,
                         ids=[f"{s}-{m}-{p}" for s, m, p in ALL_CASES])
def test_bucketed_vs_unrolled_vs_ref(bucket_case):
    scenario, mode, path, an, f_ref, fb, fu = bucket_case
    for name, f in (("bucketed", fb), ("unrolled", fu)):
        tag = (scenario, mode, path, name)
        assert np.abs(np.asarray(f.vals) - f_ref.vals).max() < 1e-10, tag
        assert np.array_equal(np.asarray(f.inode_perm), f_ref.inode_perm), tag
        assert int(f.n_perturb) == f_ref.n_perturb, tag
    assert np.abs(np.asarray(fb.vals) - np.asarray(fu.vals)).max() < 1e-10


@pytest.mark.parametrize("mode", MODES)
def test_perturbation_count_parity(mode):
    """A numerically singular system (duplicate row) must trigger the same
    pivot perturbations — count and positions — in all three engines."""
    rng = np.random.default_rng(7)
    a = sp.random(26, 26, density=0.18,
                  random_state=np.random.RandomState(3), format="lil")
    a = a + sp.diags(rng.uniform(1, 2, 26))
    a[9, :] = a[4, :]                      # exactly dependent rows
    Ac = CSR.from_scipy(a.tocsr())
    an = analyze(Ac, HyluOptions(force_mode=mode, bulk_min_width=2))
    m = _m_values(an, Ac)
    f_ref = ref_engine.factor(an.plan, m, perturb_eps=an.opts.perturb_eps)
    assert f_ref.n_perturb >= 1
    fb = jax.jit(make_factor_fn(an.plan, bulk_min_width=2))(
        jnp.asarray(m.data))
    assert int(fb.n_perturb) == f_ref.n_perturb
    assert np.array_equal(np.asarray(fb.inode_perm), f_ref.inode_perm)
    assert np.abs(np.asarray(fb.vals) - f_ref.vals).max() < 1e-8


@pytest.mark.parametrize("mode", MODES)
def test_default_bulk_width_end_to_end(mode):
    """With the production bulk_min_width the engine must still solve to
    refinement accuracy (the schedule then mixes unrolled bulk levels,
    per-node sequential nodes and scanned width-1 tails)."""
    Ac, a_sp, b, _ = scenario_system("circuit", n=40, seed=11)
    an = analyze(Ac, HyluOptions(force_mode=mode, engine="jax"))
    st = factor(an, Ac)
    x, info = solve(st, b)
    assert info["residual"] < 1e-10, mode
