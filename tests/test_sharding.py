"""Sharded batched-engine parity suite (the degenerate and real sharding
paths of PR 4's multi-device repeated-solve engine).

The sharded programs must be *bit-identical* (asserted to 1e-10, observed
0.0) to the single-device path: shard_map gives every device the identical
per-system program on its K/D shard and no collective touches the
numerics.  Covered here:

* 1-device mesh ≡ unsharded (the shard_map wrapper itself is a no-op);
* K not divisible by the device count (pad with system 0 + mask, slice
  back);
* committed device buffers in, and the donating sequence pipeline;
* a real 2/4-virtual-device CPU run via the
  ``XLA_FLAGS=--xla_force_host_platform_device_count`` harness, in a
  subprocess (the flag is read once at backend init, so the multi-device
  cases cannot run inside the already-initialized test process).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import CSR, HyluOptions, analyze
from repro.core.api import (factor_batched, solve_batched, solve_sequence,
                            _solve_batched_hostloop)

from tests.helpers import scenario_system

K = 5            # deliberately not divisible by any multi-device count
N = 36
SCENARIOS_RUN = ["circuit", "banded"]


def _case(scenario, k=K, seed=3):
    Ac, _, _, _ = scenario_system(scenario, n=N, seed=seed)
    rng = np.random.default_rng(seed + 10)
    vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (k, Ac.nnz))
    bb = rng.normal(size=(k, Ac.n))
    return Ac, vb, bb


def _solve(Ac, vb, bb, opts):
    an = analyze(Ac, opts)
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    return x, info, bst


@pytest.mark.parametrize("scenario", SCENARIOS_RUN)
def test_one_device_mesh_equals_unsharded(scenario):
    """mesh=1 routes through shard_map + padding machinery but must equal
    the plain vmapped path to 1e-10 (it is in fact bit-identical)."""
    Ac, vb, bb = _case(scenario)
    x0, info0, _ = _solve(Ac, vb, bb, HyluOptions())
    x1, info1, bst1 = _solve(Ac, vb, bb, HyluOptions(mesh=1))
    assert bst1.k == K and bst1.k_pad == K        # 1 device: no padding
    np.testing.assert_allclose(x1, x0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(info1["residual"], info0["residual"],
                               rtol=0, atol=1e-10)
    np.testing.assert_array_equal(info1["n_refine_per_system"],
                                  info0["n_refine_per_system"])


def test_one_device_mesh_multirhs_and_hostloop_oracle():
    Ac, vb, _ = _case("circuit")
    rng = np.random.default_rng(0)
    bm = rng.normal(size=(K, Ac.n, 3))
    x0, _, _ = _solve(Ac, vb, bm, HyluOptions())
    x1, info1, bst1 = _solve(Ac, vb, bm, HyluOptions(mesh=1))
    assert x1.shape == (K, Ac.n, 3)
    np.testing.assert_allclose(x1, x0, rtol=0, atol=1e-10)
    # the host-loop oracle slices mesh padding off and must agree too
    xh, _ = _solve_batched_hostloop(bst1, bm)
    np.testing.assert_allclose(xh, x1, rtol=0, atol=1e-10)


def test_device_buffer_input_no_reupload():
    """Committed jax arrays are used in place (the H2D-fix satellite):
    values_dev must BE the staged input buffer, and the lazily
    materialized host oracle must round-trip exactly."""
    import jax.numpy as jnp

    Ac, vb, bb = _case("circuit")
    an = analyze(Ac, HyluOptions())
    vdev = jnp.asarray(vb)
    bst = factor_batched(an, Ac, vdev)
    assert bst.values_dev is vdev                 # no copy, no round-trip
    assert bst._values_host is None               # oracle not materialized
    x, _ = solve_batched(bst, bb)
    x0, _ = solve_batched(factor_batched(an, Ac, vb), bb)
    np.testing.assert_allclose(x, x0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(bst.values_batch, vb, rtol=0, atol=0)


def test_donating_solve_consumes_state():
    Ac, vb, bb = _case("circuit")
    an = analyze(Ac, HyluOptions())
    x0, _ = solve_batched(factor_batched(an, Ac, vb), bb)
    bst = factor_batched(an, Ac, vb)
    xd, _ = solve_batched(bst, bb, donate=True)
    np.testing.assert_allclose(xd, x0, rtol=0, atol=1e-10)
    assert bst.consumed
    with pytest.raises(RuntimeError, match="consumed"):
        solve_batched(bst, bb)


@pytest.mark.parametrize("donate", [False, True])
def test_sequence_pipeline_matches_per_step_solves(donate):
    """The async double-buffered T-step pipeline (with and without buffer
    donation) must match T independent factor_batched+solve_batched calls."""
    Ac, vb, bb = _case("circuit")
    rng = np.random.default_rng(5)
    steps = [Ac.data[None, :] * rng.uniform(0.9, 1.1, (K, Ac.nnz))
             for _ in range(4)]
    xs, info = solve_sequence(Ac, steps, bb, HyluOptions(donate=donate))
    assert xs.shape == (4, K, Ac.n)
    assert info["steps"] == 4 and info["k"] == K
    an = analyze(Ac, HyluOptions())
    for t, vt in enumerate(steps):
        xt, it = solve_batched(factor_batched(an, Ac, vt), bb)
        np.testing.assert_allclose(xs[t], xt, rtol=0, atol=1e-10)
        np.testing.assert_allclose(info["residual"][t], it["residual"],
                                   rtol=0, atol=1e-10)


def test_sequence_per_step_rhs_and_stacked_values():
    Ac, vb, bb = _case("circuit")
    rng = np.random.default_rng(6)
    steps = np.stack([Ac.data[None, :] * rng.uniform(0.9, 1.1, (K, Ac.nnz))
                      for _ in range(3)])            # (T, K, nnz) stacked
    bs = [rng.normal(size=(K, Ac.n)) for _ in range(3)]
    xs, info = solve_sequence(Ac, steps, bs)
    an = analyze(Ac, HyluOptions())
    for t in range(3):
        xt, _ = solve_batched(factor_batched(an, Ac, steps[t]), bs[t])
        np.testing.assert_allclose(xs[t], xt, rtol=0, atol=1e-10)
    with pytest.raises(ValueError, match="per-step right-hand sides"):
        solve_sequence(Ac, steps, bs[:2])


def test_sequence_donate_shared_committed_rhs():
    """A committed jax RHS shared across steps must survive donation: the
    pipeline restages a fresh copy per step instead of dispatching the
    step-0-donated buffer again (regression: 'array has been deleted')."""
    import jax.numpy as jnp

    Ac, vb, bb = _case("circuit")
    rng = np.random.default_rng(8)
    steps = [Ac.data[None, :] * rng.uniform(0.9, 1.1, (K, Ac.nnz))
             for _ in range(3)]
    b_dev = jnp.asarray(bb)
    xs, _ = solve_sequence(Ac, steps, b_dev, HyluOptions(donate=True))
    xs0, _ = solve_sequence(Ac, steps, bb, HyluOptions())
    np.testing.assert_allclose(xs, xs0, rtol=0, atol=1e-10)
    np.testing.assert_allclose(np.asarray(b_dev), bb)  # caller's b intact


def test_wrong_batch_size_rhs_raises():
    """A mis-sized RHS batch must raise, not silently zero-pad."""
    Ac, vb, bb = _case("circuit")
    an = analyze(Ac, HyluOptions())
    bst = factor_batched(an, Ac, vb)
    with pytest.raises(ValueError, match="batch size"):
        solve_batched(bst, bb[: K - 2])


def test_list_of_1d_value_sets_is_one_batched_step():
    """Historical semantics: a list of (nnz,) vectors is ONE K-batch, not
    a K-step sequence of 1-system batches."""
    Ac, vb, bb = _case("circuit")
    x_list, info = solve_sequence(Ac, [vb[i] for i in range(K)], bb)
    assert x_list.shape == (K, Ac.n)
    x_arr, _ = solve_sequence(Ac, vb, bb)
    np.testing.assert_allclose(x_list, x_arr, rtol=0, atol=1e-10)


def test_mesh_option_validation():
    Ac, vb, bb = _case("circuit")
    with pytest.raises(TypeError, match="mesh must be"):
        _solve(Ac, vb, bb, HyluOptions(mesh="four"))
    import jax

    if len(jax.devices()) == 1:
        with pytest.raises(ValueError, match="devices are visible|visible"):
            _solve(Ac, vb, bb, HyluOptions(mesh=2))


# --------------------------------------------------------------------------
# real multi-device runs: a subprocess sets
# --xla_force_host_platform_device_count before jax initializes
# --------------------------------------------------------------------------
_MULTI_DEVICE_CODE = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import sys
sys.path.insert(0, "tests")
from helpers import scenario_system
from repro.core import HyluOptions, analyze
from repro.core.api import factor_batched, solve_batched, solve_sequence
from repro.launch.mesh import ensure_virtual_cpu_devices, make_solver_mesh

assert ensure_virtual_cpu_devices(4) >= 4

for scenario in {scenarios!r}:
    Ac, _, _, _ = scenario_system(scenario, n=36, seed=3)
    rng = np.random.default_rng(13)
    vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (5, Ac.nnz))   # K=5
    bb = rng.normal(size=(5, Ac.n))
    an0 = analyze(Ac, HyluOptions())
    x0, info0 = solve_batched(factor_batched(an0, Ac, vb), bb)
    for nd in (2, 4):                         # K=5 divides neither: pad+mask
        for mesh in (nd, make_solver_mesh(nd)):   # int and explicit Mesh
            an = analyze(Ac, HyluOptions(mesh=mesh))
            bst = factor_batched(an, Ac, vb)
            assert bst.k == 5 and bst.k_pad % nd == 0 and bst.k_pad >= 5
            x, info = solve_batched(bst, bb)
            assert np.abs(x - x0).max() <= 1e-10, (scenario, nd)
            assert np.abs(info["residual"] - info0["residual"]).max() <= 1e-10
            assert x.shape == x0.shape
    # donating sequence pipeline on 2 devices
    steps = [Ac.data[None, :] * rng.uniform(0.9, 1.1, (5, Ac.nnz))
             for _ in range(3)]
    xs, _ = solve_sequence(Ac, steps, bb, HyluOptions(mesh=2, donate=True))
    xs0, _ = solve_sequence(Ac, steps, bb, HyluOptions())
    assert np.abs(xs - xs0).max() <= 1e-10, scenario
print("MULTI_DEVICE_PARITY_OK")
"""


def _run_multi_device(scenarios):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c",
         _MULTI_DEVICE_CODE.format(scenarios=scenarios)],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "MULTI_DEVICE_PARITY_OK" in r.stdout, (r.stdout[-2000:],
                                                  r.stderr[-4000:])


def test_multi_device_parity_subprocess():
    """2- and 4-virtual-device sharding ≡ single device, K=5 non-divisible,
    int and Mesh options, donating pipeline — in a fresh process so the
    device-count flag can take effect."""
    if len(__import__("jax").devices()) >= 4:
        pytest.skip("already multi-device in-process; covered by "
                    "test_multi_device_parity_inprocess")
    _run_multi_device(SCENARIOS_RUN)


def test_multi_device_parity_inprocess():
    """The same parity matrix run directly when the process already has ≥2
    devices — this is the path the CI multi-device job exercises (it sets
    XLA_FLAGS before pytest starts)."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >=2 devices (CI multi-device job sets "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    nds = [d for d in (2, 4) if len(jax.devices()) >= d]
    for scenario in SCENARIOS_RUN:
        Ac, vb, bb = _case(scenario)
        x0, info0, _ = _solve(Ac, vb, bb, HyluOptions())
        for nd in nds:
            x, info, bst = _solve(Ac, vb, bb, HyluOptions(mesh=nd))
            assert bst.k_pad % nd == 0
            np.testing.assert_allclose(x, x0, rtol=0, atol=1e-10)
            np.testing.assert_allclose(info["residual"], info0["residual"],
                                       rtol=0, atol=1e-10)
