"""JAX engine ≡ numpy reference; differentiable solve; Pallas path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.api import HyluOptions, analyze, _m_values
from repro.core.jax_engine import make_factor_fn, make_lu_solver
from repro.core.structure import build_solve_structure
from repro.core.autodiff import make_sparse_solve
from repro.core import ref_engine

from tests.helpers import random_system


@pytest.mark.parametrize("mode", ["rowrow", "hybrid"])
def test_jax_factor_matches_ref(mode):
    Ac, _, _ = random_system(90, 0.06, 21)
    an = analyze(Ac, HyluOptions(force_mode=mode))
    m = _m_values(an, Ac)
    f_ref = ref_engine.factor(an.plan, m)
    f_jax = jax.jit(make_factor_fn(an.plan))(jnp.asarray(m.data))
    assert np.abs(np.asarray(f_jax.vals) - f_ref.vals).max() < 1e-11
    assert np.array_equal(np.asarray(f_jax.inode_perm), f_ref.inode_perm)
    assert int(f_jax.n_perturb) == f_ref.n_perturb


def test_jax_solve_and_transpose_solve():
    Ac, a_sp, b = random_system(70, 0.07, 23)
    an = analyze(Ac)
    m = _m_values(an, Ac)
    f = jax.jit(make_factor_fn(an.plan))(jnp.asarray(m.data))
    ss = build_solve_structure(an.plan)
    lu_solve, lut_solve = make_lu_solver(ss)
    from repro.core.ref_engine import extract_lu, factor as rfactor
    fr = rfactor(an.plan, m)
    l, u = extract_lu(fr)
    ld, ud = l.to_dense(), u.to_dense()
    rng = np.random.default_rng(0)
    c = rng.normal(size=70)
    w = np.asarray(lu_solve(f.vals, jnp.asarray(c)))
    w_ref = np.linalg.solve(ud, np.linalg.solve(ld, c))
    assert np.abs(w - w_ref).max() < 1e-9
    wt = np.asarray(lut_solve(f.vals, jnp.asarray(c)))
    wt_ref = np.linalg.solve(ld.T, np.linalg.solve(ud.T, c))
    assert np.abs(wt - wt_ref).max() < 1e-9


@pytest.mark.parametrize("mode", ["rowrow", "hybrid"])
def test_sparse_solve_grads(mode):
    Ac, a_sp, b = random_system(60, 0.07, 29)
    an = analyze(Ac, HyluOptions(force_mode=mode))
    solve = make_sparse_solve(an)
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=60))

    def loss(ad, bb):
        return jnp.sum(w * solve(ad, bb))

    g_a, g_b = jax.grad(loss, argnums=(0, 1))(
        jnp.asarray(Ac.data), jnp.asarray(b))
    eps = 1e-6
    for t in rng.choice(Ac.nnz, 4, replace=False):
        d = Ac.data.copy()
        d[t] += eps
        lp = float(loss(jnp.asarray(d), jnp.asarray(b)))
        d[t] -= 2 * eps
        lm = float(loss(jnp.asarray(d), jnp.asarray(b)))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - float(g_a[t])) < 1e-4 * (1 + abs(fd))
    for t in rng.choice(60, 3, replace=False):
        bb = b.copy()
        bb[t] += eps
        lp = float(loss(jnp.asarray(Ac.data), jnp.asarray(bb)))
        bb[t] -= 2 * eps
        lm = float(loss(jnp.asarray(Ac.data), jnp.asarray(bb)))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - float(g_b[t])) < 1e-4 * (1 + abs(fd))


def test_jax_engine_pallas_path():
    Ac, _, _ = random_system(50, 0.1, 31)
    an = analyze(Ac, HyluOptions(force_mode="hybrid"))
    m = _m_values(an, Ac)
    f_ref = ref_engine.factor(an.plan, m)
    f_jax = jax.jit(make_factor_fn(an.plan, use_pallas=True,
                                   interpret=True))(jnp.asarray(m.data))
    assert np.abs(np.asarray(f_jax.vals) - f_ref.vals).max() < 1e-10
