"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step, output shapes, finiteness; decode ≡ teacher-forced forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.shapes import SHAPES, cell_applicable, input_specs
from repro.models import transformer as T
from repro.train.train_step import loss_fn, make_train_step
from repro.optim import adamw

ARCHS = sorted(registry.ARCHS)


def _mk_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = dict(
        tokens=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        labels=jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32))
    if cfg.embeddings_input:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)) * 0.02, jnp.float32)
    if cfg.rope_type == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, None, :], (3, b, s))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch):
    cfg = registry.get(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _mk_batch(cfg)
    loss, metrics = jax.jit(
        lambda p, bt: loss_fn(cfg, p, bt, seq_chunk=16))(params, batch)
    assert np.isfinite(float(loss))
    hidden, aux, _ = T.forward(cfg, params, tokens=batch["tokens"],
                               embeds=batch.get("embeds"),
                               positions=batch.get("positions"))
    assert hidden.shape == (2, 32, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_updates(arch):
    cfg = registry.get(arch).reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = make_train_step(cfg, opt_cfg, seq_chunk=16)
    opt = adamw.init_state(params)
    batch = _mk_batch(cfg)
    p2, opt2, _, metrics = jax.jit(step)(params, opt, None, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = registry.get(arch).reduced()
    if cfg.moe is not None:   # no-drop capacity for exact teacher forcing
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = T.init_params(cfg, jax.random.PRNGKey(1), dtype=jnp.float32)
    rng = np.random.default_rng(2)
    b, s, n_new = 2, 16, 3
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + n_new)), jnp.int32)
    emb = jnp.asarray(rng.normal(size=(b, s + n_new, cfg.d_model)) * 0.02,
                      jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s + n_new, dtype=jnp.int32)[None, None],
                           (3, b, s + n_new))
    fkw = {}
    if cfg.embeddings_input:
        fkw["embeds"] = emb
    if cfg.rope_type == "mrope":
        fkw["positions"] = pos
    hidden, _, _ = T.forward(cfg, params,
                             tokens=None if cfg.embeddings_input else toks,
                             remat=False, **fkw)
    full = T.lm_logits(cfg, params, hidden)

    from repro.serve.serve_step import make_prefill_step
    pkw = {}
    if cfg.embeddings_input:
        pkw["embeds"] = emb[:, :s]
    if cfg.rope_type == "mrope":
        pkw["positions"] = pos[:, :, :s]
    prefill = make_prefill_step(cfg, s_max=s + n_new)
    logits, cache = prefill(
        params, tokens=None if cfg.embeddings_input else toks[:, :s], **pkw)
    errs = [float(jnp.abs(logits[:, -1] - full[:, s - 1]).max())]
    for i in range(n_new):
        p = s + i
        dkw = {}
        if cfg.embeddings_input:
            dkw["embeds"] = emb[:, p:p + 1]
        if cfg.rope_type == "mrope":
            dkw["positions"] = pos[:, :, p:p + 1]
        lg, cache = T.decode_step(
            cfg, params, None if cfg.embeddings_input else toks[:, p:p + 1],
            cache, jnp.asarray(p, jnp.int32), **dkw)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, p]).max()))
    assert max(errs) < 2e-3, errs


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_complete(arch):
    cfg = registry.get(arch)
    for sn, shape in SHAPES.items():
        ok, _ = cell_applicable(cfg, shape)
        if not ok:
            continue
        specs = input_specs(cfg, shape)
        assert "tokens" in specs
        if shape.kind == "decode":
            assert "cache" in specs and "pos" in specs


def test_param_counts_in_range():
    """Sanity: configured params land near the advertised model sizes."""
    expect = {
        "phi3-medium-14b": (12e9, 16e9),
        "internlm2-20b": (17e9, 23e9),
        "gemma-7b": (7e9, 10e9),
        "command-r-plus-104b": (90e9, 115e9),
        "grok-1-314b": (280e9, 340e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "jamba-1.5-large-398b": (330e9, 430e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "qwen2-vl-7b": (6e9, 9e9),
        "musicgen-medium": (1.2e9, 2.4e9),
    }
    for name, (lo, hi) in expect.items():
        n = registry.get(name).param_count()
        assert lo < n < hi, (name, n / 1e9)
