import numpy as np
import scipy.sparse as sp

from repro.core.matrix import CSR


def random_system(n, density, seed, kind="general"):
    """Deterministic random nonsingular sparse system."""
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="csr")
    a = a + sp.diags(rng.uniform(1.0, 3.0 if kind == "circuit" else 2.0, n)
                     * rng.choice([-1, 1], n))
    a = a.tocsr()
    b = rng.normal(size=n)
    return CSR.from_scipy(a), a, b
