import numpy as np
import scipy.sparse as sp

from repro.core.matrix import CSR


def random_system(n, density, seed, kind="general"):
    """Deterministic random nonsingular sparse system."""
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="csr")
    a = a + sp.diags(rng.uniform(1.0, 3.0 if kind == "circuit" else 2.0, n)
                     * rng.choice([-1, 1], n))
    a = a.tocsr()
    b = rng.normal(size=n)
    return CSR.from_scipy(a), a, b


# --------------------------------------------------------------------------
# scenario matrix: the structurally distinct workloads the batched solver
# must handle.  Each generator is deterministic in (n, seed) and returns a
# nonsingular system; `expected_mode` is what kernel_select should route it
# to at default thresholds (asserted by tests/test_kernel_select.py).
# --------------------------------------------------------------------------
def circuit_system(n=36, seed=0):
    """Circuit-like: extremely sparse, strong diagonal, a few random
    couplings per node (the KLU/NICSLU workload) → rowrow kernels."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for i in range(n):
        for j in rng.integers(0, n, 2):
            if j != i:
                rows.append(i); cols.append(int(j))
    vals = rng.uniform(-1.0, 1.0, len(rows))
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    a = a + sp.diags(rng.uniform(2.0, 4.0, n) * rng.choice([-1, 1], n))
    return a.tocsr()


def banded_system(n=36, seed=0, half_bw=6):
    """Banded/PDE-like: dense band of half-bandwidth `half_bw` (discretized
    operator shape) — contiguous fill makes wide supernodes → hybrid."""
    rng = np.random.default_rng(seed)
    diags, offs = [], []
    for o in range(-half_bw, half_bw + 1):
        m = n - abs(o)
        d = rng.uniform(-1.0, 1.0, m)
        if o == 0:
            d = rng.uniform(1.0, 2.0, m) * (2 * half_bw + 1)
        diags.append(d); offs.append(o)
    return sp.diags(diags, offs, shape=(n, n)).tocsr()


def denseish_system(n=36, seed=0, density=0.5):
    """Dense-ish: high fill-in, nearly full LU → hybrid with wide
    supernodes."""
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="csr")
    a = a + sp.diags(rng.uniform(float(n) / 2, float(n), n))
    return a.tocsr()


def singleton_system(n=36, seed=0):
    """Singleton-heavy: most rows carry only their diagonal (decoupled
    unknowns), a small coupled core — exercises width-1 nodes and the
    near-empty levels of the solve schedule → rowrow."""
    rng = np.random.default_rng(seed)
    core = max(4, n // 6)
    rows, cols = [], []
    for i in range(core):
        for j in range(core):
            if i != j and rng.random() < 0.5:
                rows.append(i); cols.append(j)
    vals = rng.uniform(-1.0, 1.0, len(rows))
    a = sp.coo_matrix((vals, (rows, cols)), shape=(n, n)).tocsr()
    a = a + sp.diags(rng.uniform(1.0, 3.0, n) * rng.choice([-1, 1], n))
    return a.tocsr()


# name -> (generator, routing_n, expected_mode, routing_kwargs).
# expected_mode is what kernel_select routes the scenario to at
# routing-scale (gen(n=routing_n, **routing_kwargs)) with default
# thresholds: circuit/singleton stay below the NICSLU flops/nnz criterion
# (rowrow); dense-ish crosses it at n≈80 → hybrid; banded/PDE bands have
# flops/nnz ≈ half-bandwidth, so the routing-scale band is widened to
# half_bw=48 where the discretized-operator class genuinely lands on the
# hybrid supernodal kernels (at default half_bw=6 a band is circuit-like
# and correctly routes rowrow — the other tests keep using that size).
SCENARIOS = {
    "circuit": (circuit_system, 48, "rowrow", {}),
    "banded": (banded_system, 144, "hybrid", {"half_bw": 48}),
    "denseish": (denseish_system, 80, "hybrid", {}),
    "singleton": (singleton_system, 48, "rowrow", {}),
}


def scenario_system(name, n=36, seed=0):
    """(CSR, scipy_csr, b, expected_mode) for one named scenario.
    expected_mode refers to routing at SCENARIOS' routing scale, not n."""
    gen, _, expected_mode, _ = SCENARIOS[name]
    a = gen(n=n, seed=seed)
    b = np.random.default_rng(seed + 1).normal(size=n)
    return CSR.from_scipy(a), a, b, expected_mode


def routing_system(name, seed=0):
    """(CSR, b, expected_mode) for one named scenario AT ROUTING SCALE —
    the size/shape where kernel_select's thresholds route it to its
    intended kernel mode (circuit→rowrow, banded/denseish→hybrid)."""
    gen, routing_n, expected_mode, kwargs = SCENARIOS[name]
    a = gen(n=routing_n, seed=seed, **kwargs)
    b = np.random.default_rng(seed + 1).normal(size=routing_n)
    return CSR.from_scipy(a), b, expected_mode


def empty_row_pattern(n=8, seed=0):
    """A CSR *pattern* (indptr, indices, nnz) with genuinely empty rows —
    not solvable, used to exercise the empty-row branches of the batched
    matvec utilities."""
    rng = np.random.default_rng(seed)
    indptr = [0]
    indices = []
    for i in range(n):
        if i % 3 == 0:                      # every third row empty
            indptr.append(indptr[-1])
            continue
        cols = np.unique(rng.integers(0, n, 3))
        indices.extend(cols.tolist())
        indptr.append(len(indices))
    return (np.asarray(indptr, dtype=np.int64),
            np.asarray(indices, dtype=np.int64))
