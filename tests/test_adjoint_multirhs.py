"""Gradient/adjoint coverage for the transpose-solve (lut_solve) and the
multi-RHS path: adjointness of the LU substitution pair, and
finite-difference checks of d(solve)/d(a_data) through the differentiable
solver vmapped over RHS columns, on a small hybrid-mode matrix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HyluOptions, analyze
from repro.core.api import _m_values, jax_repeated_engine
from repro.core.autodiff import make_sparse_solve

from tests.helpers import random_system


@pytest.fixture(scope="module")
def hybrid_case():
    """One shared hybrid-mode analysis (and thus one shared engine jit
    cache) for the whole module."""
    Ac, a_sp, b = random_system(40, 0.12, 37)
    an = analyze(Ac, HyluOptions(force_mode="hybrid", engine="jax"))
    return Ac, a_sp, b, an


def test_lut_solve_is_adjoint_of_lu_solve(hybrid_case):
    """⟨U⁻¹L⁻¹ c, d⟩ == ⟨c, L⁻ᵀU⁻ᵀ d⟩ for random c, d — lut_solve is the
    exact adjoint of the forward substitution on the same factors."""
    from repro.core.jax_engine import make_lu_solver, make_factor_fn
    from repro.core.structure import build_solve_structure

    Ac, a_sp, b, an = hybrid_case
    m = _m_values(an, Ac)
    f = jax.jit(make_factor_fn(an.plan))(jnp.asarray(m.data))
    ss = build_solve_structure(an.plan)
    lu_solve, lut_solve = make_lu_solver(ss)
    rng = np.random.default_rng(0)
    for _ in range(3):
        c = rng.normal(size=Ac.n)
        d = rng.normal(size=Ac.n)
        lhs = float(np.dot(np.asarray(lu_solve(f.vals, jnp.asarray(c))), d))
        rhs = float(np.dot(c, np.asarray(lut_solve(f.vals, jnp.asarray(d)))))
        assert abs(lhs - rhs) < 1e-9 * (1 + abs(lhs))


def test_engine_lut_solve_transpose_residual(hybrid_case):
    """The engine's jitted lut_solve composes (with the analysis
    permutations applied in reverse) to a solve of Aᵀ y = g."""
    Ac, a_sp, b, an = hybrid_case
    eng = jax_repeated_engine(an)
    jf = eng.refactor(jnp.asarray(Ac.data))
    rng = np.random.default_rng(3)
    g = rng.normal(size=Ac.n)
    # adjoint chain (see autodiff.make_sparse_solve bwd): Aᵀ y = g
    s = an.match.col_scale
    r = an.match.row_scale
    t = (s * g)[an.q][an.p]
    t = np.asarray(eng.lut_solve(jf.vals, jnp.asarray(t)))
    z = np.zeros(Ac.n)
    z[np.asarray(jf.inode_perm)] = t
    y = np.zeros(Ac.n)
    y[an.p] = z
    y = r * y
    resid = np.abs(a_sp.T @ y - g).sum() / np.abs(g).sum()
    assert resid < 1e-10


def test_multi_rhs_solve_grads_fd(hybrid_case):
    """Finite-difference check of d(solve)/d(a_data) with the solve vmapped
    over M RHS columns — the adjoint/sensitivity workload shape."""
    Ac, a_sp, b, an = hybrid_case
    solve = make_sparse_solve(an)
    msolve = jax.vmap(solve, in_axes=(None, 1), out_axes=1)   # (n, M) rhs
    rng = np.random.default_rng(11)
    M = 3
    B = rng.normal(size=(Ac.n, M))
    W = rng.normal(size=(Ac.n, M))

    def loss(a_data, bb):
        return jnp.sum(jnp.asarray(W) * msolve(a_data, bb))

    g_a, g_b = jax.grad(loss, argnums=(0, 1))(
        jnp.asarray(Ac.data), jnp.asarray(B))
    eps = 1e-6
    for t in rng.choice(Ac.nnz, 4, replace=False):
        d = Ac.data.copy()
        d[t] += eps
        lp = float(loss(jnp.asarray(d), jnp.asarray(B)))
        d[t] -= 2 * eps
        lm = float(loss(jnp.asarray(d), jnp.asarray(B)))
        fd = (lp - lm) / (2 * eps)
        assert abs(fd - float(g_a[t])) < 1e-4 * (1 + abs(fd)), t
    # RHS gradient: d loss / d B = Aᵀ-solve of W, checked by FD on a few
    for t in rng.choice(Ac.n, 2, replace=False):
        for j in (0, M - 1):
            bb = B.copy()
            bb[t, j] += eps
            lp = float(loss(jnp.asarray(Ac.data), jnp.asarray(bb)))
            bb[t, j] -= 2 * eps
            lm = float(loss(jnp.asarray(Ac.data), jnp.asarray(bb)))
            fd = (lp - lm) / (2 * eps)
            assert abs(fd - float(g_b[t, j])) < 1e-4 * (1 + abs(fd))


def test_fused_multirhs_consistent_with_autodiff_forward(hybrid_case):
    """The fused batched multi-RHS solve and the differentiable scalar solve
    agree on the same systems (K=1 batch, M columns)."""
    from repro.core.api import factor_batched, solve_batched

    Ac, a_sp, b, an = hybrid_case
    solve = make_sparse_solve(an)
    rng = np.random.default_rng(23)
    M = 2
    B = rng.normal(size=(Ac.n, M))
    bst = factor_batched(an, Ac, Ac.data[None, :])
    x_fused, info = solve_batched(bst, B[None, :, :])
    assert info["residual"].max() < 1e-10
    for j in range(M):
        x_ad = np.asarray(solve(jnp.asarray(Ac.data), jnp.asarray(B[:, j])))
        assert np.abs(x_fused[0, :, j] - x_ad).max() \
            / (np.abs(x_ad).max() + 1e-30) < 1e-9
