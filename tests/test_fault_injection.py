"""Chaos suite: the serving robustness contract under injected faults.

Drives the full fault matrix (``serve.faultinject.FAULT_KINDS`` — NaN/Inf
values, NaN RHS, wrong-shape RHS, numerically singular and ill-conditioned
systems, deadline storms) plus queue-overflow pressure through the async
server and the synchronous service, asserting the contract the serving
tier lives by:

* every submitted request receives exactly ONE terminal result
  (solved / rejected / failed / quarantined) — zero losses;
* zero silently-wrong results — a non-converged solution is never
  returned as ``solved``;
* healthy requests sharing a batch with poisoned neighbors still match an
  independent dense-fp64 oracle to <=1e-10;
* one pattern group's dispatch exception cannot lose another group's
  results (error isolation);
* the escalation ladder runs end to end: refine → fp64 fallback →
  perturbed re-factor retries → quarantine with diagnostics.
"""
import asyncio

import numpy as np
import pytest

from repro.core.plan_cache import PlanCache
from repro.serve import faultinject
from repro.serve.async_server import AsyncSolverServer
from repro.serve.faultinject import (build_pattern, healthy_values, inject,
                                     fp64_oracle, make_stream, run_stream,
                                     check_report, _with_values,
                                     FAULT_KINDS)
from repro.serve.solver_service import (SolverService, InvalidRequestError,
                                        ERR_NONFINITE_VALUES,
                                        ERR_NONFINITE_RHS,
                                        ERR_SHAPE_MISMATCH, ERR_QUEUE_FULL,
                                        ERR_DISPATCH, ERR_QUARANTINED,
                                        STATUS_SOLVED, STATUS_REJECTED,
                                        STATUS_FAILED, STATUS_QUARANTINED,
                                        TERMINAL_STATUSES)

N = 24  # system size: small enough that per-pattern compiles stay cheap

# one shared in-memory plan cache so the suite's patterns analyze and
# compile once across tests (engines live on the cached Analysis objects)
_CACHE = PlanCache(capacity=64, directory=None)


def _service(batch_size=4, **opt_kw):
    from repro.core import HyluOptions
    return SolverService(opts=HyluOptions(**opt_kw), cache=_CACHE,
                         batch_size=batch_size)


# ---------------------------------------------------------------- the storm
def test_fault_storm_exactly_one_terminal_result_each():
    """The headline contract: a mixed-pattern stream interleaving ALL
    fault kinds with healthy traffic through the async server — zero
    lost, zero silent-wrong, per-kind expected statuses, healthy
    fp64-oracle parity <=1e-10."""
    async def main():
        async with AsyncSolverServer(_service(), max_queue_per_group=128,
                                     max_pending=256,
                                     max_linger_ms=20.0) as server:
            stream = make_stream(40, fault_rate=0.35, seed=5, n=N)
            return await run_stream(server, stream), stream

    report, stream = asyncio.run(main())
    kinds = {item.kind for item in stream if item.kind}
    assert len(kinds) >= 5, f"fault mix too thin: {kinds}"
    violations = check_report(report)
    assert not violations, "\n".join(violations)
    assert report["lost"] == 0
    assert report["n_outcomes"] == len(stream)
    assert set(report["by_status"]) <= set(TERMINAL_STATUSES)
    assert report["by_status"][STATUS_SOLVED] > 0
    assert report["by_status"][STATUS_REJECTED] > 0
    assert report["worst_healthy_err"] <= faultinject.ORACLE_RTOL
    assert report["n_healthy_checked"] > 0


def test_healthy_neighbors_keep_fp64_parity_in_poisoned_batch():
    """Healthy requests batched WITH a numerically-singular and an
    ill-conditioned neighbor (same pattern group, same vmapped dispatch)
    still match the dense fp64 oracle — per-lane numerics are isolated."""
    pat = build_pattern("circuit", n=N, seed=1)
    rng = np.random.default_rng(7)
    healthy = [( _with_values(pat, healthy_values(pat, 100 + i)),
                 rng.standard_normal(N)) for i in range(3)]
    singular = inject("singular_values", pat, seed=8)
    ill = inject("ill_conditioned", pat, seed=9)

    svc = _service(batch_size=8)
    reqs = [healthy[0], (singular.a, singular.b), healthy[1],
            (ill.a, ill.b), healthy[2]]
    res = svc.solve_batch(reqs)
    assert all(r.status in TERMINAL_STATUSES for r in res)
    for (a, b), r in zip(healthy, (res[0], res[2], res[4])):
        assert r.status == STATUS_SOLVED and not r.refine_failed
        x0 = fp64_oracle(a, b)
        err = np.abs(r.x - x0).max() / np.abs(x0).max()
        assert err <= 1e-10, err
    for r in (res[1], res[3]):
        # poisoned neighbors are never returned as silent garbage
        assert r.status in (STATUS_QUARANTINED, STATUS_FAILED,
                            STATUS_SOLVED)
        if r.status == STATUS_SOLVED:
            assert not r.refine_failed


# ------------------------------------------------------------- admission
def test_admission_rejects_are_typed():
    pat = build_pattern("banded", n=N, seed=1)
    svc = _service()
    cases = dict(nan_values=ERR_NONFINITE_VALUES,
                 inf_values=ERR_NONFINITE_VALUES,
                 nan_rhs=ERR_NONFINITE_RHS,
                 wrong_shape_rhs=ERR_SHAPE_MISMATCH)
    # sync submit(): eager typed raise, nothing enters the window
    for kind, code in cases.items():
        bad = inject(kind, pat, seed=11)
        with pytest.raises(InvalidRequestError) as ei:
            svc.submit(bad.a, bad.b)
        assert ei.value.error.code == code
    assert svc.flush() == []

    # solve_batch(): typed rejected result in place, neighbors untouched
    good_a = _with_values(pat, healthy_values(pat, 12))
    good_b = np.random.default_rng(12).standard_normal(N)
    bad = inject("nan_values", pat, seed=13)
    res = svc.solve_batch([(good_a, good_b), (bad.a, bad.b)])
    assert res[0].status == STATUS_SOLVED
    assert res[1].status == STATUS_REJECTED
    assert res[1].error.code == ERR_NONFINITE_VALUES
    assert res[1].x is None
    assert svc.stats["rejected"] == 1

    # async submit(): same eager typed raise
    async def main():
        async with AsyncSolverServer(_service()) as server:
            with pytest.raises(InvalidRequestError) as ei:
                await server.submit(bad.a, bad.b)
            return ei.value.error.code

    assert asyncio.run(main()) == ERR_NONFINITE_VALUES


def test_queue_overflow_backpressure_is_typed_not_unbounded():
    """Submitting past the bounded per-group queue yields immediate typed
    ``queue_full`` rejections; every admitted request still resolves on
    drain — exactly one terminal result per submit either way."""
    pat = build_pattern("circuit", n=N, seed=1)
    rng = np.random.default_rng(3)

    async def main():
        server = AsyncSolverServer(
            _service(batch_size=None), max_queue_per_group=4,
            max_pending=64,
            max_linger_ms=10_000.0)   # no time-based flush: pressure builds
        futs = []
        async with server:
            for i in range(10):
                a = _with_values(pat, healthy_values(pat, 200 + i))
                futs.append(await server.submit(a, rng.standard_normal(N),
                                                tag=i))
            # exactly the overflow (10 - 4) must already be rejected
            done = [f for f in futs if f.done()]
            assert len(done) == 6
            for f in done:
                r = f.result()
                assert r.status == STATUS_REJECTED
                assert r.error.code == ERR_QUEUE_FULL
                assert r.error.detail["scope"] == "group"
        # context exit drains: the 4 admitted requests resolve solved
        results = [await f for f in futs]
        stats = server.stats()
        return results, stats

    results, stats = asyncio.run(main())
    assert len(results) == 10
    assert sum(r.status == STATUS_SOLVED for r in results) == 4
    assert sum(r.status == STATUS_REJECTED for r in results) == 6
    assert stats["rejected_full"] == 6
    assert stats["reject_rate"] == pytest.approx(0.6)
    assert stats["queue_depth"] == 0


def test_deadline_storm_flushes_and_flags_misses():
    """A storm of microscopic deadlines: the deadline trigger flushes
    partially-full batches immediately, nothing is dropped for lateness,
    and every late completion is flagged + counted."""
    pat = build_pattern("banded", n=N, seed=1)
    rng = np.random.default_rng(4)

    async def main():
        server = AsyncSolverServer(
            _service(batch_size=8), max_queue_per_group=64, max_pending=64,
            max_linger_ms=10_000.0,   # only the deadline trigger can flush
            deadline_margin_ms=0.5)
        async with server:
            futs = []
            for i in range(6):
                a = _with_values(pat, healthy_values(pat, 300 + i))
                futs.append(await server.submit(
                    a, rng.standard_normal(N), tag=i, deadline_ms=1e-3))
            results = [await f for f in futs]
        return results, server.stats()

    results, stats = asyncio.run(main())
    assert all(r.status == STATUS_SOLVED for r in results)
    assert all(not r.refine_failed for r in results)
    # a 1 us budget is always missed — and the miss is data, not a drop
    assert all(r.deadline_missed for r in results)
    assert all(r.latency_s is not None for r in results)
    assert stats["deadline_misses"] == 6
    assert stats["deadline_miss_rate"] == pytest.approx(6 / 6)


# ------------------------------------------------------- escalation ladder
def test_singular_values_walk_the_ladder_to_quarantine():
    """A numerically singular system (structurally fine) survives
    admission, fails refinement, consumes its perturbed re-factor
    retries, and lands in quarantine with diagnostics — never a silent
    NaN solution."""
    pat = build_pattern("circuit", n=N, seed=1)
    bad = inject("singular_values", pat, seed=21)
    svc = _service(batch_size=4, retry_max=2)
    res = svc.solve_batch([(bad.a, bad.b)])
    r = res[0]
    assert r.status == STATUS_QUARANTINED
    assert r.error.code == ERR_QUARANTINED
    assert r.n_retries == 2
    assert svc.stats["retries"] == 2
    assert svc.stats["quarantined"] == 1
    d = r.error.detail
    assert d["n_retries"] == 2 and "residual" in d and "n_perturb" in d


def test_retry_opts_route_through_distinct_fingerprints():
    """The ladder's retries factor under a boosted perturb_eps — an
    explicit plan-option change, so they hit their own plan-cache entries
    and never mutate the healthy traffic's engines."""
    from repro.core.options import (HyluOptions, plan_fingerprint,
                                    resolve_retry_perturb,
                                    resolve_perturb_eps)

    pat = build_pattern("circuit", n=N, seed=1)
    opts = HyluOptions()
    fp0 = plan_fingerprint(pat, opts)
    e1 = resolve_retry_perturb(opts, 1)
    e2 = resolve_retry_perturb(opts, 2)
    assert e1 == pytest.approx(resolve_perturb_eps(opts)
                               * opts.retry_perturb_boost)
    assert e2 > e1
    import dataclasses
    fp1 = plan_fingerprint(pat, dataclasses.replace(opts, perturb_eps=e1))
    fp2 = plan_fingerprint(pat, dataclasses.replace(opts, perturb_eps=e2))
    assert len({fp0, fp1, fp2}) == 3
    with pytest.raises(ValueError):
        resolve_retry_perturb(opts, 0)


# ---------------------------------------------------------- group isolation
def test_dispatch_exception_in_one_group_cannot_lose_other_groups(
        monkeypatch):
    """Satellite bugfix regression: an exception inside ONE pattern
    group's dispatch yields typed ``failed`` results for that group only —
    the other groups' computed results are returned, not lost (the seed
    behavior raised out of flush and dropped the whole window)."""
    import repro.serve.solver_service as ss
    from repro.core.options import plan_fingerprint

    pat_ok = build_pattern("circuit", n=N, seed=1)
    pat_boom = build_pattern("denseish", n=N, seed=1)
    svc = _service(batch_size=4)
    fp_boom = plan_fingerprint(pat_boom, svc.opts)

    real = ss.factor_batched

    def exploding(an, pattern, vb, *a, **kw):
        if an.fingerprint == fp_boom:
            raise RuntimeError("injected dispatch explosion")
        return real(an, pattern, vb, *a, **kw)

    monkeypatch.setattr(ss, "factor_batched", exploding)

    rng = np.random.default_rng(6)
    reqs, kinds = [], []
    for i in range(6):
        pat = (pat_boom, pat_ok)[i % 2]
        kinds.append("boom" if pat is pat_boom else "ok")
        reqs.append((_with_values(pat, healthy_values(pat, 400 + i)),
                     rng.standard_normal(N)))
    res = svc.solve_batch(reqs)
    assert len(res) == 6 and all(r is not None for r in res)
    for kind, r in zip(kinds, res):
        if kind == "ok":
            assert r.status == STATUS_SOLVED and r.x is not None
        else:
            assert r.status == STATUS_FAILED
            assert r.error.code == ERR_DISPATCH
            assert "injected dispatch explosion" in r.error.message
            assert r.error.detail["stage"] == "dispatch"
            assert r.x is None
    assert svc.stats["failed"] == 3

    # flush() path: the window is cleared even with the poisoned group
    for a, b in reqs:
        svc.submit(a, b)
    out = svc.flush()
    assert len(out) == 6
    assert svc.flush() == []    # queue actually cleared


def test_async_window_survives_service_level_exception():
    """Belt-and-braces: if solve_batch itself ever raised, the async
    dispatch barrier turns the whole window into typed failed results
    rather than hanging the futures."""
    class ExplodingService:
        opts = SolverService(cache=_CACHE).opts
        batch_size = 4
        stats = dict(rejected=0, retries=0, quarantined=0, failed=0)

        def _opts_for(self, req, retry_attempt=0):
            return self.opts

        def solve_batch(self, reqs):
            raise RuntimeError("whole-window explosion")

    pat = build_pattern("circuit", n=N, seed=1)

    async def main():
        server = AsyncSolverServer(ExplodingService(),
                                   max_linger_ms=5.0)
        async with server:
            fut = await server.submit(
                _with_values(pat, healthy_values(pat, 500)),
                np.random.default_rng(9).standard_normal(N))
            return await fut

    r = asyncio.run(main())
    assert r.status == STATUS_FAILED
    assert r.error.code == ERR_DISPATCH
    assert r.error.detail["stage"] == "window"
    assert "whole-window explosion" in r.error.message
