"""Layer-level invariants: RoPE/M-RoPE, chunked attention vs dense oracle,
MoE dispatch conservation, Mamba/RWKV seq ≡ step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.configs import registry
from repro.configs.base import ArchConfig, MoECfg
from repro.models import layers as L

RNG = np.random.default_rng(7)


# --------------------------------------------------------------------- rope
def test_rope_preserves_norm_and_relativity():
    x = jnp.asarray(RNG.normal(size=(2, 8, 4, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relativity: <q_i, k_j> depends only on i-j
    q = jnp.asarray(RNG.normal(size=(1, 10, 1, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 10, 1, 16)), jnp.float32)
    q = jnp.broadcast_to(q[:, :1], q.shape)   # same content every position
    k = jnp.broadcast_to(k[:, :1], k.shape)
    pos = jnp.arange(10, dtype=jnp.int32)[None]
    qr, kr = L.apply_rope(q, pos, 1e4), L.apply_rope(k, pos, 1e4)
    dots = np.einsum("bthd,bshd->ts", np.asarray(qr), np.asarray(kr))
    for off in (1, 3):
        d = np.diagonal(dots, offset=off)
        assert np.allclose(d, d[0], rtol=1e-4)


def test_mrope_sections_match_std_rope_when_positions_equal():
    """With identical t/h/w position streams, M-RoPE == standard RoPE."""
    x = jnp.asarray(RNG.normal(size=(2, 6, 2, 16)), jnp.float32)
    pos1 = jnp.broadcast_to(jnp.arange(6, dtype=jnp.int32)[None], (2, 6))
    pos3 = jnp.broadcast_to(pos1[None], (3, 2, 6))
    y_std = L.apply_rope(x, pos1, 1e4)
    y_m = L.apply_mrope(x, pos3, 1e4, (3, 3, 2))
    np.testing.assert_allclose(np.asarray(y_std), np.asarray(y_m), atol=1e-6)


# ---------------------------------------------------------------- attention
def _dense_causal(q, k, v):
    b, t, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    kk = np.repeat(np.asarray(k), g, axis=2)
    vv = np.repeat(np.asarray(v), g, axis=2)
    logit = np.einsum("bthd,bshd->bhts", np.asarray(q), kk) / np.sqrt(d)
    mask = np.tril(np.ones((t, t), bool))
    logit = np.where(mask, logit, -1e30)
    w = np.exp(logit - logit.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    return np.einsum("bhts,bshd->bthd", w, vv)


@pytest.mark.parametrize("t,chunk", [(16, 8), (33, 8), (64, 16), (7, 16)])
def test_chunked_attention_vs_dense(t, chunk):
    q = jnp.asarray(RNG.normal(size=(2, t, 4, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, t, 2, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, t, 2, 16)), jnp.float32)
    o = L._chunked_causal_attention(q, k, v, chunk_k=chunk)
    o_ref = _dense_causal(q, k, v)
    np.testing.assert_allclose(np.asarray(o), o_ref, atol=2e-5, rtol=2e-5)


def test_chunked_attention_grads_finite():
    q = jnp.asarray(RNG.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 32, 1, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 32, 1, 8)), jnp.float32)
    g = jax.grad(lambda q_: L._chunked_causal_attention(
        q_, k, v, chunk_k=8).sum())(q)
    assert np.all(np.isfinite(np.asarray(g)))


# ---------------------------------------------------------------------- moe
def _moe_cfg(e=4, k=2, cf=8.0):
    return ArchConfig(name="t", family="moe", n_layers=2, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=0, vocab=64,
                      moe=MoECfg(n_experts=e, top_k=k, d_ff_expert=8,
                                 capacity_factor=cf))


def test_moe_no_drop_equals_dense_expert_mix():
    """With huge capacity, the sort-based dispatch must equal the exact
    per-token expert mixture computed densely."""
    cfg = _moe_cfg()
    p = L.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 6, 16)), jnp.float32)
    out, aux = L.moe(cfg, p, x)
    # dense oracle
    xf = np.asarray(x).reshape(-1, 16)
    logits = xf @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    top = np.argsort(-probs, axis=-1)[:, :2]
    ref = np.zeros_like(xf)
    for t in range(xf.shape[0]):
        gsum = probs[t, top[t]].sum()
        for e in top[t]:
            g_ = np.asarray(jax.nn.silu(xf[t] @ np.asarray(p["w_gate"][e])))
            u_ = xf[t] @ np.asarray(p["w_up"][e])
            y = (g_ * u_) @ np.asarray(p["w_down"][e])
            ref[t] += probs[t, e] / gsum * y
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), ref,
                               atol=1e-4, rtol=1e-4)
    assert float(aux["moe_lb"]) >= 0.99  # LB loss >= 1 in expectation-ish


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)
    p = L.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(4, 8, 16)), jnp.float32)
    out, _ = L.moe(cfg, p, x)
    assert np.all(np.isfinite(np.asarray(out)))
    # some token outputs must be exactly zero (dropped)
    norms = np.linalg.norm(np.asarray(out).reshape(-1, 16), axis=-1)
    assert (norms == 0).any()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_moe_dispatch_conservation(seed):
    """Conservation: with all experts sharing identical weights and no
    capacity drops, routing must be invisible — the MoE equals a plain MLP
    applied to every token (each kept pair combined exactly once with gates
    summing to 1)."""
    cfg = _moe_cfg(e=4, k=2, cf=8.0)
    p = L.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(seed)
    d = 16
    x = jnp.asarray(rng.normal(size=(2, 5, d)), jnp.float32)
    p = dict(p)
    for key in ("w_gate", "w_up", "w_down"):
        p[key] = jnp.broadcast_to(p[key][:1], p[key].shape)
    out, _ = L.moe(cfg, p, x)
    ref = (jax.nn.silu(x @ p["w_gate"][0]) * (x @ p["w_up"][0])) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------- mamba/rwkv
def test_mamba_seq_equals_step():
    cfg = registry.get("jamba-1.5-large-398b").reduced()
    p = L.init_mamba(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 20, cfg.d_model)), jnp.float32)
    o_seq, (conv, h) = L.mamba_seq(cfg, p, x, chunk=8, return_state=True)
    m = cfg.mamba
    di = m.expand * cfg.d_model
    st_ = (jnp.zeros((2, m.d_conv - 1, di)), jnp.zeros((2, di, m.d_state)))
    outs = []
    for t in range(20):
        o, st_ = L.mamba_step(cfg, p, x[:, t:t + 1], st_)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(o_seq),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(st_[1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(conv), np.asarray(st_[0]), atol=1e-5)


def test_rwkv_seq_equals_step():
    cfg = registry.get("rwkv6-1.6b").reduced()
    p = L.init_rwkv(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 12, cfg.d_model)) * 0.3, jnp.float32)
    o_seq, st_fin = L.rwkv_time_mix_seq(cfg, p, x, return_state=True)
    d = cfg.d_model
    nh = d // cfg.rwkv_head_size
    st_ = (jnp.zeros((2, d)), jnp.zeros((2, nh, cfg.rwkv_head_size,
                                         cfg.rwkv_head_size)))
    outs = []
    for t in range(12):
        o, st_ = L.rwkv_time_mix_step(cfg, p, x[:, t:t + 1], st_)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(o_seq),
                               np.asarray(jnp.stack(outs, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_fin[1]), np.asarray(st_[1]),
                               atol=1e-4)


def test_rms_norm_scale_invariance():
    x = jnp.asarray(RNG.normal(size=(3, 8)), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    y1 = L.rms_norm(x, w)
    y2 = L.rms_norm(7.3 * x, w)
    # eps breaks exact invariance; tolerance reflects eps/var ratio
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
