"""Docs stay true: the docs-lint checks run as part of the suite, so a
broken internal link, an undocumented HyluOptions field, or an unlinked
core doc fails tier-1 — not just the dedicated CI step."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_lint_passes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, os.path.join(REPO, "tools",
                                                     "docs_lint.py")],
                       capture_output=True, text=True, env=env, cwd=REPO)
    assert r.returncode == 0, r.stderr


def test_core_docs_exist():
    for rel in ("docs/ARCHITECTURE.md", "docs/API.md", "docs/BENCHMARKS.md"):
        assert os.path.exists(os.path.join(REPO, rel)), rel
