"""End-to-end solver correctness: HYLU vs scipy (SuperLU), all kernel modes,
refactorization, iterative refinement, residual properties (§2, Figs 5–11)."""
import numpy as np
import pytest
import scipy.sparse as sp
import scipy.sparse.linalg as spla
from tests._hyp import given, settings, st

from repro.core.matrix import CSR
from repro.core.api import (HyluOptions, analyze, factor, refactor, solve,
                            solve_system, _m_values)
from repro.core.ref_engine import extract_lu
from repro.core import baselines

from tests.helpers import random_system

MODES = [None, "rowrow", "hybrid", "supernodal"]


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("seed", [0, 1])
def test_solve_matches_scipy(mode, seed):
    Ac, a_sp, b = random_system(150, 0.04, seed)
    x_ref = spla.spsolve(a_sp.tocsc(), b)
    x, info = solve_system(Ac, b, HyluOptions(force_mode=mode))
    assert info["residual"] < 1e-10
    assert np.abs(x - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 1e-6


def test_lu_reconstruction():
    Ac, _, _ = random_system(120, 0.05, 7)
    an = analyze(Ac)
    st_ = factor(an, Ac)
    l, u = extract_lu(st_.factors)
    m = _m_values(an, Ac).to_dense()
    rec = l.to_dense() @ u.to_dense()
    assert np.abs(rec - m[st_.factors.inode_perm, :]).max() < 1e-9


def test_refactor_same_pattern():
    Ac, a_sp, b = random_system(100, 0.05, 3)
    an = analyze(Ac)
    st_ = factor(an, Ac)
    rng = np.random.default_rng(0)
    a2 = a_sp.copy()
    a2.data = a2.data * rng.uniform(0.5, 2.0, a2.nnz)
    st2 = refactor(st_, CSR.from_scipy(a2.tocsr()))
    x, info = solve(st2, b)
    x_ref = spla.spsolve(a2.tocsc(), b)
    assert info["residual"] < 1e-10
    assert np.abs(x - x_ref).max() / np.abs(x_ref).max() < 1e-6


def test_refactor_plan_is_reused():
    Ac, _, _ = random_system(80, 0.06, 9)
    an = analyze(Ac)
    st_ = factor(an, Ac)
    st2 = refactor(st_, Ac)
    assert st2.analysis is st_.analysis          # analysis shared, not rebuilt
    assert np.abs(st2.factors.vals - st_.factors.vals).max() < 1e-14


def test_pivot_perturbation_and_refinement():
    """A tiny pivot that static pivoting can't avoid triggers perturbation +
    iterative refinement recovers a residual comparable to a dense solve
    (§2.2/§2.3 — like the paper's Hamrle3 case, accuracy is bounded by the
    condition number, not by the solver)."""
    rng = np.random.default_rng(11)
    n = 40
    a = np.where(rng.random((n, n)) < 0.2, rng.normal(size=(n, n)), 0.0)
    a += np.diag(rng.uniform(1, 2, n))
    # make one row a near-duplicate → tiny pivot somewhere
    a[7, :] = a[3, :] + 1e-10 * rng.normal(size=n)
    b = rng.normal(size=n)
    x, info = solve_system(CSR.from_dense(a), b)
    assert info["n_perturb"] >= 1           # perturbation fired
    assert info["n_refine"] >= 1            # refinement engaged
    resid = np.abs(a @ x - b).sum() / np.abs(b).sum()
    # accuracy is condition-limited (cond ~1e10+, like the paper's Hamrle3
    # case); require a usable residual, not machine precision
    assert resid < 5e-2


def test_residual_metric_matches_paper_definition():
    Ac, a_sp, b = random_system(60, 0.08, 5)
    x, info = solve_system(Ac, b)
    resid = np.abs(a_sp @ x - b).sum() / np.abs(b).sum()
    assert abs(resid - info["residual"]) < 1e-12 + 0.1 * resid


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(10, 90),
       st.floats(0.03, 0.2), st.sampled_from(["rowrow", "hybrid"]))
def test_solver_property(seed, n, density, mode):
    """Property: for any nonsingular system, residual < 1e-8 and the solve
    agrees with a dense solve."""
    rng = np.random.default_rng(seed)
    a = np.where(rng.random((n, n)) < density, rng.normal(size=(n, n)), 0.0)
    a += np.diag(rng.uniform(1, 3, n) * rng.choice([-1, 1], n))
    b = rng.normal(size=n)
    x, info = solve_system(CSR.from_dense(a), b,
                           HyluOptions(force_mode=mode))
    assert info["residual"] < 1e-8
    x_ref = np.linalg.solve(a, b)
    assert np.abs(x - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 1e-5


def test_kernel_selection_modes():
    """Circuit-like extreme sparsity selects row-row; denser selects hybrid."""
    Ac, _, _ = random_system(300, 0.006, 13, kind="circuit")
    an = analyze(Ac)
    assert an.choice.mode == "rowrow", an.choice
    Ad, _, _ = random_system(150, 0.2, 13)
    an2 = analyze(Ad)
    assert an2.choice.mode in ("hybrid", "supernodal"), an2.choice


def test_baseline_presets():
    Ac, a_sp, b = random_system(90, 0.06, 17)
    x_ref = spla.spsolve(a_sp.tocsc(), b)
    for name, mk in baselines.BASELINES.items():
        x, info = solve_system(Ac, b, mk())
        assert info["residual"] < 1e-9, name
