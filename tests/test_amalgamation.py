"""Supernode amalgamation invariants (``HyluOptions.amalg_fill_tol``).

The contract: amalgamation is a *scheduling* transform — merged panels
carry exact numeric zeros in their structural-zero slots, so the
amalgamated plan factors to the same L/U values and every engine solves
to the same answer (1e-10 here; the difference is pure float summation
order).  fill_tol=0 must reproduce the historical plan bit-for-bit.
"""
import numpy as np
import pytest

from repro.core.api import HyluOptions, analyze, factor, solve
from repro.core.structure import amalgamate_supernodes

from tests.helpers import SCENARIOS, scenario_system


def _plans_equal(p0, p1):
    if p0.n_nodes != p1.n_nodes or p0.total_slots != p1.total_slots:
        return False
    for a, b in zip(p0.nodes, p1.nodes):
        if (a.r0, a.r1, a.lsize, a.usize, a.level) != \
                (b.r0, b.r1, b.lsize, b.usize, b.level):
            return False
        if not np.array_equal(a.pattern, b.pattern):
            return False
        if len(a.edges) != len(b.edges):
            return False
        for ea, eb in zip(a.edges, b.edges):
            if ea.src != eb.src or not np.array_equal(ea.col_map,
                                                      eb.col_map):
                return False
    return True


@pytest.mark.parametrize("name", sorted(SCENARIOS))
@pytest.mark.parametrize("engine", ["ref", "jax"])
def test_amalgamated_solve_matches_plain(name, engine):
    """Across the scenario matrix and both engines: the amalgamated plan's
    solution agrees with the unamalgamated one to 1e-10."""
    Ac, _, b, _ = scenario_system(name, n=120, seed=7)
    x_plain, info_plain = solve(
        factor(analyze(Ac, HyluOptions()), Ac, engine=engine), b)
    an = analyze(Ac, HyluOptions(amalg_fill_tol=1.0))
    x_amalg, info_amalg = solve(factor(an, Ac, engine=engine), b)
    assert np.max(np.abs(x_plain - x_amalg)) < 1e-10
    assert info_amalg["residual"] < 1e-8
    assert "amalg" in an.choice.stats


def test_fill_tol_zero_reproduces_plan_exactly():
    """fill_tol=0 is bit-for-bit the historical plan (same partition, same
    node patterns/edges/levels), and the amalgamation hook doesn't run."""
    Ac, _, _, _ = scenario_system("circuit", n=100, seed=3)
    an0 = analyze(Ac, HyluOptions())
    an1 = analyze(Ac, HyluOptions(amalg_fill_tol=0.0))
    assert _plans_equal(an0.plan, an1.plan)
    assert np.array_equal(an0.sym.snode_start, an1.sym.snode_start)
    assert np.array_equal(an0.sym.snode_of, an1.sym.snode_of)
    assert "amalg" not in an1.choice.stats
    assert "amalgamate" not in an1.timings


def test_amalgamation_merges_near_identical_columns():
    """A dense-ish matrix has runs of independent near-identical columns:
    amalgamation must actually coarsen the partition and record it."""
    Ac, _, _, _ = scenario_system("denseish", n=100, seed=5)
    an0 = analyze(Ac, HyluOptions())
    an1 = analyze(Ac, HyluOptions(amalg_fill_tol=1.0))
    st = an1.choice.stats["amalg"]
    assert st["n_merges"] > 0
    assert st["n_nodes_after"] == st["n_nodes_before"] - st["n_merges"]
    assert len(an1.plan.nodes) < len(an0.plan.nodes)
    assert len(an1.plan.nodes) == st["n_nodes_after"]


def test_amalgamate_supernodes_partition_invariants():
    """The coarsened Symbolic stays a consecutive-row partition: starts
    strictly ascend from 0, ends chain to n, snode_of is consistent, and
    every merge respects max_super."""
    Ac, _, _, _ = scenario_system("denseish", n=90, seed=11)
    an = analyze(Ac, HyluOptions())
    sym2, st = amalgamate_supernodes(an.sym, fill_tol=2.0, max_super=8)
    starts, ends = sym2.snode_start, sym2.snode_end
    assert starts[0] == 0 and ends[-1] == sym2.n
    assert np.all(starts[1:] == ends[:-1])
    # max_super bounds *merges*; a node symbolic_factorize already made
    # wider passes through untouched.  So every new node is either an
    # original node verbatim or a merge within the cap.
    orig = set(zip(an.sym.snode_start.tolist(), an.sym.snode_end.tolist()))
    for r0, r1 in zip(starts.tolist(), ends.tolist()):
        assert (r0, r1) in orig or r1 - r0 <= 8
    for t in range(len(starts)):
        assert np.all(sym2.snode_of[starts[t]:ends[t]] == t)
    assert st["n_nodes_after"] == len(starts)
    # the untouched symbolic fields are shared, not copied
    assert sym2.lrow_ptr is an.sym.lrow_ptr
    assert sym2.lcol_ptr is an.sym.lcol_ptr


def test_amalgamation_independence_preserved():
    """Merged nodes must be mutually independent (no filled L/U entry
    between constituents): inside every merged node, no row's filled L-row
    structure reaches another constituent row of the same node.  This is
    the guarantee that keeps level structure — and the scanned width-1
    tail of the bucketed schedule — intact."""
    Ac, _, _, _ = scenario_system("denseish", n=100, seed=5)
    an0 = analyze(Ac, HyluOptions())
    width0 = dict()
    for t in range(len(an0.sym.snode_start)):
        width0[int(an0.sym.snode_start[t])] = (
            int(an0.sym.snode_end[t]) - int(an0.sym.snode_start[t]))
    an1 = analyze(Ac, HyluOptions(amalg_fill_tol=1.0))
    sym = an1.sym
    for t in range(len(sym.snode_start)):
        r0, r1 = int(sym.snode_start[t]), int(sym.snode_end[t])
        # walk the original nodes inside [r0, r1): dependencies may exist
        # inside one original node (its own panel), never across them
        cut = r0 + width0.get(r0, r1 - r0)
        cuts = [r0]
        while cut < r1:
            cuts.append(cut)
            cut += width0.get(cut, r1 - cut)
        for i in range(r0, r1):
            lr = sym.lrow_idx[sym.lrow_ptr[i]:sym.lrow_ptr[i + 1]]
            own_start = max(c for c in cuts if c <= i)
            cross = lr[(lr >= r0) & (lr < own_start)]
            assert cross.size == 0, (t, i, cross)


def test_analyze_records_amalg_timing():
    Ac, _, _, _ = scenario_system("denseish", n=80, seed=2)
    an = analyze(Ac, HyluOptions(amalg_fill_tol=0.5))
    assert "amalgamate" in an.timings
    assert an.timings["total"] >= an.timings["amalgamate"]
