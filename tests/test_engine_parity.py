"""API-level ref ≡ jax engine parity in all three kernel modes, plus the
batched repeated-solve path against a Python loop of refactor.

The jax engine must produce bit-comparable factors (same panels, same
in-node pivot choices, same perturbation count) and solves within
float64 round-off of the reference engine; the batched path must match a
Python loop of single refactorizations exactly (it is the same program,
vmapped)."""
import numpy as np
import pytest
import scipy.sparse.linalg as spla

from repro.core import (CSR, HyluOptions, analyze, factor, refactor, solve,
                        factor_batched, solve_batched, solve_sequence)
from repro.core.api import _m_values
from repro.core import ref_engine
from repro.core.ref_engine import factor_value_loop

from tests.helpers import random_system

MODES = ["rowrow", "hybrid", "supernodal"]


@pytest.fixture(scope="module", params=MODES)
def mode_state(request):
    """One analysis per kernel mode, shared across this module's tests so
    the jax engine (and its jit cache) compiles once per mode."""
    mode = request.param
    Ac, a_sp, b = random_system(44, 0.08, 5)
    an = analyze(Ac, HyluOptions(force_mode=mode, engine="jax"))
    return mode, Ac, a_sp, b, an


def test_factor_parity(mode_state):
    mode, Ac, a_sp, b, an = mode_state
    st = factor(an, Ac)                       # engine="jax" from opts
    assert st.engine == "jax"
    f_ref = ref_engine.factor(an.plan, _m_values(an, Ac),
                              perturb_eps=an.opts.perturb_eps)
    assert np.abs(np.asarray(st.jax_factors.vals) - f_ref.vals).max() < 1e-11
    assert np.array_equal(np.asarray(st.jax_factors.inode_perm),
                          f_ref.inode_perm)
    assert int(st.jax_factors.n_perturb) == f_ref.n_perturb


def test_solve_parity(mode_state):
    mode, Ac, a_sp, b, an = mode_state
    st_jax = factor(an, Ac)
    x_jax, info_jax = solve(st_jax, b)
    st_ref = factor(an, Ac, engine="ref")
    x_ref, info_ref = solve(st_ref, b)
    assert info_jax["residual"] < 1e-10, mode
    assert info_ref["residual"] < 1e-10, mode
    scale = np.abs(x_ref).max() + 1e-30
    assert np.abs(x_jax - x_ref).max() / scale < 1e-9


def test_refactor_parity(mode_state):
    mode, Ac, a_sp, b, an = mode_state
    rng = np.random.default_rng(3)
    a2 = CSR(Ac.n, Ac.indptr, Ac.indices,
             Ac.data * rng.uniform(0.8, 1.2, Ac.nnz))
    st2 = refactor(factor(an, Ac), a2)        # jax: one pre-compiled call
    x2, info2 = solve(st2, b)
    assert info2["residual"] < 1e-10, mode
    x_ref = spla.spsolve(a2.to_scipy().tocsc(), b)
    assert np.abs(x2 - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 1e-6


def test_batched_matches_refactor_loop(mode_state):
    """factor_batched/solve_batched ≡ a Python loop of refactor + solve —
    both against the jitted scalar path and the numpy reference loop."""
    mode, Ac, a_sp, b, an = mode_state
    k = 5
    rng = np.random.default_rng(11)
    vb = Ac.data[None, :] * rng.uniform(0.8, 1.2, (k, Ac.nnz))
    bb = rng.normal(size=(k, Ac.n))

    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    assert info["residual"].shape == (k,)
    assert info["residual"].max() < 1e-10, mode

    # numpy reference loop over the same value sets (M-space values)
    mb = vb[:, an.src_map] * an.scale_map
    refs = factor_value_loop(an.plan, an.m_pattern, mb,
                             perturb_eps=an.opts.perturb_eps)
    vals_b = np.asarray(bst.vals)
    inode_b = np.asarray(bst.inode_perm)
    for i, fr in enumerate(refs):
        # rowrow chains long scalar recurrences → slightly looser round-off
        assert np.abs(vals_b[i] - fr.vals).max() < 1e-9, (mode, i)
        assert np.array_equal(inode_b[i], fr.inode_perm), (mode, i)
        assert bst.n_perturb[i] == fr.n_perturb, (mode, i)

    # x parity against the scalar jitted refactor path
    st = factor(an, Ac)
    for i in range(k):
        sti = refactor(st, CSR(Ac.n, Ac.indptr, Ac.indices, vb[i]))
        xi, _ = solve(sti, bb[i])
        assert np.abs(xi - x[i]).max() / (np.abs(xi).max() + 1e-30) < 1e-9


def test_solve_sequence_end_to_end():
    """One-call batched repeated solve vs scipy ground truth per system."""
    Ac, a_sp, b = random_system(40, 0.09, 9)
    k = 4
    rng = np.random.default_rng(2)
    vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (k, Ac.nnz))
    bb = rng.normal(size=(k, Ac.n))
    x, info = solve_sequence(Ac, vb, bb)
    assert info["k"] == k
    assert info["engine"] == "jax-batched"
    assert info["residual"].max() < 1e-10
    for i in range(k):
        ai = a_sp.copy()
        ai.data = vb[i].copy()
        x_ref = spla.spsolve(ai.tocsc(), bb[i])
        assert np.abs(x[i] - x_ref).max() / (np.abs(x_ref).max() + 1e-30) < 1e-6


def test_solve_sequence_broadcast_rhs():
    """(n,) rhs broadcasts across the batch."""
    Ac, a_sp, b = random_system(36, 0.1, 13)
    vb = np.stack([Ac.data, Ac.data * 1.05])
    x, info = solve_sequence(Ac, vb, b)
    assert x.shape == (2, Ac.n)
    assert info["residual"].max() < 1e-10
