"""End-to-end behaviour tests for the paper's system: full pipeline on the
benchmark-suite matrix classes (smallest instances) — analysis, hybrid
factorization, solve, refactor — one pass per class."""
import sys
import os

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core.api import analyze, factor, refactor, solve
from repro.core.matrix import CSR


CLASSES = ["circuit", "asic", "powergrid", "fem2d", "fem3d", "banded",
           "kkt", "unsym"]


@pytest.mark.parametrize("cls", CLASSES)
def test_end_to_end_per_matrix_class(cls):
    from benchmarks import matrices as M
    gen = {
        "circuit": lambda: M.circuit_like(400, 1),
        "asic": lambda: M.asic_like(400, 2),
        "powergrid": lambda: M.powergrid_like(16, 18, 3),
        "fem2d": lambda: M.fem2d(14, 14, 4),
        "fem3d": lambda: M.fem3d(5, 5, 5, 5),
        "banded": lambda: M.banded(300, 6, 6),
        "kkt": lambda: M.kkt(200, 60, 7),
        "unsym": lambda: M.unsym_random(300, 0.01, 8),
    }[cls]
    a_sp = gen().tocsr()
    a_sp.sort_indices()
    Ac = CSR.from_scipy(a_sp)
    rng = np.random.default_rng(0)
    b = rng.normal(size=Ac.n)
    an = analyze(Ac)
    st = factor(an, Ac)
    x, info = solve(st, b)
    assert info["residual"] < 1e-8, (cls, info)
    # repeated-solve path
    a2 = CSR(Ac.n, Ac.indptr, Ac.indices,
             Ac.data * rng.uniform(0.9, 1.1, Ac.nnz))
    st2 = refactor(st, a2)
    x2, info2 = solve(st2, b)
    assert info2["residual"] < 1e-8, (cls, info2)
