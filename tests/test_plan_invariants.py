"""Property tests on the FactorPlan — the paper's core data structure.

Invariants (hypothesis over random sparse systems):
  - panel slots partition the storage exactly (no overlap, no gaps);
  - every edge's col_map hits real pattern positions of the target;
  - edges reference only earlier nodes (DAG), sources ascend;
  - levelization is a topological schedule (dual-mode split consistent);
  - A-scatter positions are unique and in-range;
  - plan flops accounting: useful ≤ padded.
"""
import numpy as np
import scipy.sparse as sp
from tests._hyp import given, settings, st

from repro.core.api import HyluOptions, analyze
from repro.core.matrix import CSR


def _analysis(seed, n, density, mode, amalg_fill_tol=0.0):
    rng = np.random.default_rng(seed)
    a = sp.random(n, n, density=density,
                  random_state=np.random.RandomState(seed), format="csr")
    a = a + sp.diags(rng.uniform(1, 2, n) * rng.choice([-1, 1], n))
    return analyze(CSR.from_scipy(a.tocsr()),
                   HyluOptions(force_mode=mode,
                               amalg_fill_tol=amalg_fill_tol))


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(10, 80), st.floats(0.03, 0.2),
       st.sampled_from(["rowrow", "hybrid", "supernodal"]),
       st.sampled_from([0.0, 0.5, 2.0]))
def test_plan_invariants(seed, n, density, mode, amalg_fill_tol):
    an = _analysis(seed, n, density, mode, amalg_fill_tol)
    plan = an.plan

    # --- panel layout partitions storage ---------------------------------
    total = 0
    for nd in plan.nodes:
        off = plan.panel_offset[nd.nid]
        assert off == total
        total += nd.nr * nd.width
    assert total == plan.total_slots

    # --- rows partition [0, n) ------------------------------------------
    covered = np.concatenate([np.arange(nd.r0, nd.r1) for nd in plan.nodes])
    assert np.array_equal(np.sort(covered), np.arange(plan.n))

    # --- patterns sorted; block present; edges consistent -----------------
    level = np.zeros(plan.n_nodes, dtype=int)
    for nd in plan.nodes:
        pat = nd.pattern
        assert np.all(np.diff(pat) > 0)
        assert np.array_equal(pat[nd.lsize:nd.lsize + nd.nr],
                              np.arange(nd.r0, nd.r1))
        prev_src = -1
        for e in nd.edges:
            assert prev_src < e.src < nd.nid        # DAG + ascending
            prev_src = e.src
            snd = plan.nodes[e.src]
            src_cols = snd.pattern[np.searchsorted(snd.pattern, snd.r0):]
            # col_map maps exactly the source block+U cols into the target
            assert len(e.col_map) == len(src_cols)
            assert np.array_equal(pat[e.col_map], src_cols)
            level[nd.nid] = max(level[nd.nid], level[e.src] + 1)
        assert level[nd.nid] == nd.level            # topological levels

    # --- dual-mode schedule covers all nodes once -------------------------
    sched = np.concatenate(plan.levels) if plan.levels else np.empty(0, int)
    assert np.array_equal(np.sort(sched), np.arange(plan.n_nodes))
    assert 0 <= plan.n_bulk_levels <= len(plan.levels)

    # --- A-scatter unique + in-range --------------------------------------
    assert len(np.unique(plan.a_scatter)) == len(plan.a_scatter)
    assert plan.a_scatter.min() >= 0
    assert plan.a_scatter.max() < plan.total_slots

    # --- flops accounting --------------------------------------------------
    assert plan.useful_flops <= plan.padded_flops + 1e-6
    if mode == "rowrow" and amalg_fill_tol == 0.0:
        # width-1 nodes: no padding waste by construction (amalgamation
        # re-fattens panels, so the equality only holds with it off)
        assert abs(plan.useful_flops - plan.padded_flops) < 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(12, 70), st.floats(0.04, 0.22),
       st.sampled_from(["rowrow", "hybrid", "supernodal"]),
       st.sampled_from([2, 8]),
       st.sampled_from([0.0, 1.0]))
def test_bucket_schedule_invariants(seed, n, density, mode, bmw,
                                    amalg_fill_tol):
    """The level-bucketed factor schedule must be a complete, non-
    overlapping re-grouping of the plan: every node's internal LU appears
    exactly once (diag bucket, panel bucket, sequential list, or scanned
    level), every edge exactly once (unrolled edge bucket or scan chunk),
    all padded indices point at the sentinel slots, and all multiplier
    scatter positions within a level are disjoint."""
    from repro.core.structure import build_bucket_schedule

    an = _analysis(seed, n, density, mode, amalg_fill_tol)
    plan = an.plan
    sched = build_bucket_schedule(plan, bulk_min_width=bmw)
    total = sched.total_slots
    sentinels = {sched.zero_slot, sched.one_slot, sched.scratch_slot}

    # --- nodes covered exactly once ---------------------------------------
    seen = []
    for s in sched.steps:
        if s.diag is not None:
            seen.extend(s.diag.nids.tolist())
            assert all(plan.nodes[t].nr == 1 for t in s.diag.nids)
        for pb in s.panels:
            seen.extend(pb.nids.tolist())
            assert all(plan.nodes[t].nr > 1 for t in pb.nids)
        seen.extend(s.seq.tolist())
    for c in sched.scan_chunks:
        for lv in range(c.lv0, c.lv1):
            nids = plan.levels[lv]
            assert all(plan.nodes[int(t)].nr == 1 for t in nids)
            seen.extend(int(t) for t in nids)
    assert np.array_equal(np.sort(np.asarray(seen)),
                          np.arange(plan.n_nodes))

    # --- edges covered exactly once ---------------------------------------
    n_edges_plan = sum(len(nd.edges) for nd in plan.nodes)
    n_edges_steps = sum(len(eb.srcs) for s in sched.steps for eb in s.edges)
    n_edges_scan = sum(int((c.x_idx < total).sum())
                       for c in sched.scan_chunks)
    assert n_edges_steps + n_edges_scan == n_edges_plan

    # --- padding discipline ------------------------------------------------
    for s in sched.steps:
        mult_slots = []
        for eb in s.edges:
            for arr, allowed in ((eb.src_idx, {sched.zero_slot,
                                               sched.one_slot}),
                                 (eb.x_idx, {sched.zero_slot}),
                                 (eb.write_idx, {sched.scratch_slot})):
                assert arr.min() >= 0 and arr.max() < sched.n_ext
                pads = arr[arr >= total]
                assert set(np.unique(pads)) <= allowed
            # source levels all equal the step's level
            assert all(plan.nodes[int(t)].level == s.level for t in eb.srcs)
            mult = eb.write_idx[:, :eb.nr * eb.k].ravel()
            mult_slots.append(mult[mult < total])
        if mult_slots:
            mult_all = np.concatenate(mult_slots)
            # multiplier write-back positions are disjoint within a level
            # (same-level sources own disjoint block columns) — the single
            # combined scatter-.add relies on this
            assert len(np.unique(mult_all)) == len(mult_all)
        for pb in s.panels:
            real = pb.scatter[pb.scatter < total]
            assert len(np.unique(real)) == len(real)
            # real slot count == the gathered panels' true storage
            expect = sum(plan.nodes[t].nr * plan.nodes[t].width
                         for t in pb.nids)
            assert len(real) == expect


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(12, 60), st.floats(0.05, 0.25))
def test_solve_structure_invariants(seed, n, density):
    from repro.core.structure import build_solve_structure
    an = _analysis(seed, n, density, "hybrid")
    ss = build_solve_structure(an.plan)
    # L forward schedule: every row finalized exactly once, deps point to
    # already-finalized rows
    seen = np.zeros(n, dtype=bool)
    for rows, cols, slot, seg in zip(ss.l_fwd.rows, ss.l_fwd.cols,
                                     ss.l_fwd.slot, ss.l_fwd.seg):
        if len(cols):
            assert seen[cols].all()
        assert not seen[rows].any()
        seen[rows] = True
        assert (slot < an.plan.total_slots).all()
    assert seen.all()
    # U backward: reverse dependency direction
    seen = np.zeros(n, dtype=bool)
    for rows, cols, slot, seg in zip(ss.u_bwd.rows, ss.u_bwd.cols,
                                     ss.u_bwd.slot, ss.u_bwd.seg):
        if len(cols):
            assert seen[cols].all()
        seen[rows] = True
    assert seen.all()
