"""kernel_select threshold routing, checked directly on the scenario matrix:
each generated scenario lands on its expected mode, force_mode always wins,
and rowrow re-runs symbolic with supernodes disabled (width-1 nodes).
Also covers the host/device batched-matvec utilities' corner branches
(empty rows, dtype preservation)."""
import numpy as np
import pytest

from repro.core import CSR, HyluOptions, analyze
from repro.core.api import _batched_matvec
from repro.core.kernel_select import (select_kernel, FLOPS_PER_NNZ_ROWROW,
                                      COVERAGE_ROWROW)
from repro.core.matching import max_weight_matching

from tests.helpers import (SCENARIOS, scenario_system, routing_system,
                           empty_row_pattern)

MODES = ["rowrow", "hybrid", "supernodal"]


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_scenario_routes_to_expected_mode(name):
    _, routing_n, expected, _ = SCENARIOS[name]
    Ac, _, expected2 = routing_system(name, seed=0)
    assert expected2 == expected and Ac.n == routing_n
    an = analyze(Ac)
    st = an.choice.stats
    assert an.choice.mode == expected, (name, an.choice.reason)
    # the routing must be explained by the thresholds, not accidental
    if expected == "rowrow":
        assert (st["flops_per_nnz"] < FLOPS_PER_NNZ_ROWROW
                or st["supernode_coverage"] < COVERAGE_ROWROW), st
    else:
        assert st["flops_per_nnz"] >= FLOPS_PER_NNZ_ROWROW, st
        assert st["supernode_coverage"] >= COVERAGE_ROWROW, st


@pytest.mark.parametrize("name", list(SCENARIOS))
@pytest.mark.parametrize("mode", MODES)
def test_force_mode_always_wins(name, mode):
    Ac, _, _, _ = scenario_system(name, n=24, seed=1)
    an = analyze(Ac, HyluOptions(force_mode=mode))
    assert an.choice.mode == mode
    assert an.choice.reason == "forced"


@pytest.mark.parametrize("name", list(SCENARIOS))
def test_rowrow_reruns_symbolic_with_width1_nodes(name):
    """rowrow must re-run symbolic with supernodes disabled: every plan node
    is a single row, regardless of what the default symbolic found."""
    Ac, _, _, _ = scenario_system(name, n=24, seed=2)
    an = analyze(Ac, HyluOptions(force_mode="rowrow"))
    assert an.sym.n_nodes == Ac.n
    assert all(nd.nr == 1 for nd in an.plan.nodes)
    # and a non-rowrow analysis of the same matrix may merge rows
    an_h = analyze(Ac, HyluOptions(force_mode="supernodal"))
    assert an_h.sym.n_nodes <= Ac.n


def test_select_kernel_consistent_with_analysis():
    """Calling select_kernel directly on the preprocessed pattern gives the
    same decision analyze() recorded."""
    Ac, _, _, _ = scenario_system("denseish", n=40, seed=0)
    an = analyze(Ac)
    # rebuild the symmetric permuted pattern exactly as analyze() does
    match = max_weight_matching(Ac)
    tracker = CSR(Ac.n, Ac.indptr.copy(), Ac.indices.copy(),
                  np.arange(Ac.nnz, dtype=np.float64))
    b2 = tracker.permute(np.arange(Ac.n), match.col_of_row.copy())
    pat2 = CSR(Ac.n, b2.indptr, b2.indices, np.ones(Ac.nnz)).sym_pattern()
    pat_m = pat2.permute(an.p, an.p)
    choice, sym = select_kernel(pat_m)
    assert choice.mode == an.choice.mode
    assert choice.stats == an.choice.stats


# --------------------------------------------------------------------------
# batched matvec corner branches (host reference + device path)
# --------------------------------------------------------------------------
def test_batched_matvec_empty_rows_and_dtype():
    indptr, indices = empty_row_pattern(n=9, seed=0)
    nnz = len(indices)
    rng = np.random.default_rng(0)
    for dtype in (np.float64, np.float32):
        vals = rng.normal(size=(2, nnz)).astype(dtype)
        x = rng.normal(size=(2, 9)).astype(dtype)
        out = _batched_matvec((indptr, indices), vals, x)
        assert out.dtype == dtype, "empty-row fallback must preserve dtype"
        # dense oracle
        for k in range(2):
            dense = np.zeros((9, 9), dtype=dtype)
            for i in range(9):
                dense[i, indices[indptr[i]:indptr[i + 1]]] = \
                    vals[k, indptr[i]:indptr[i + 1]]
            assert np.allclose(out[k], dense @ x[k], atol=1e-5)
        # empty rows produce exact zeros
        empty_rows = np.where(np.diff(indptr) == 0)[0]
        assert len(empty_rows) > 0
        assert np.all(out[:, empty_rows] == 0.0)


def test_device_matvec_matches_host_reference():
    import jax.numpy as jnp
    from repro.core.jax_engine import make_csr_matvec_batched

    indptr, indices = empty_row_pattern(n=9, seed=1)
    nnz = len(indices)
    rng = np.random.default_rng(1)
    vals = rng.normal(size=(3, nnz))
    x = rng.normal(size=(3, 9))
    mv = make_csr_matvec_batched(indptr, indices)
    out_dev = np.asarray(mv(jnp.asarray(vals), jnp.asarray(x)))
    out_host = _batched_matvec((indptr, indices), vals, x)
    assert np.abs(out_dev - out_host).max() < 1e-12
    # multi-RHS device path
    xm = rng.normal(size=(3, 9, 4))
    out_m = np.asarray(mv(jnp.asarray(vals), jnp.asarray(xm)))
    for j in range(4):
        assert np.abs(out_m[:, :, j]
                      - _batched_matvec((indptr, indices), vals,
                                        xm[:, :, j])).max() < 1e-12
