"""The float64 engine must refuse to build when jax x64 is disabled
(silent degradation to float32 would limp through refinement at ~1e-6
residuals).  Run by CI twice: in the x64 job (toggling the flag off
in-process) and in the float32-only job where x64 is off from the start."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import HyluOptions, analyze
from repro.core.api import jax_repeated_engine

from tests.helpers import random_system


def _analysis():
    Ac, _, _ = random_system(24, 0.12, 41)
    return analyze(Ac, HyluOptions(engine="jax"))


def test_float64_engine_requires_x64():
    an = _analysis()
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        with pytest.raises(RuntimeError, match="x64 is disabled"):
            jax_repeated_engine(an)
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_float32_engine_builds_without_x64():
    """Requesting float32 explicitly is the sanctioned no-x64 path; the
    engine must build and factor (to float32 accuracy) without the guard
    firing."""
    an = _analysis()
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", False)
    try:
        eng = jax_repeated_engine(an, dtype=jnp.float32)
        assert eng.dtype == jnp.float32
    finally:
        jax.config.update("jax_enable_x64", prev)


def test_x64_engine_builds_when_enabled():
    if not jax.config.jax_enable_x64:
        pytest.skip("float32-only job: x64 disabled by the environment")
    an = _analysis()
    eng = jax_repeated_engine(an)
    assert np.dtype(eng.dtype) == np.float64
