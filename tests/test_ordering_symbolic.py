"""Ordering + symbolic factorization + supernode invariants (§2.1)."""
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.matrix import CSR
from repro.core.ordering import (min_degree, rcm, nested_dissection,
                                 select_ordering)
from repro.core.symbolic import (etree, etree_col_counts, symbolic_factorize,
                                 symbolic_stats)


def _sym_pattern(rng, n, density):
    a = (rng.random((n, n)) < density).astype(float)
    a = a + a.T + np.eye(n)
    return CSR.from_dense(a)


@pytest.mark.parametrize("fn", [min_degree, rcm, nested_dissection])
def test_orderings_are_permutations(fn):
    rng = np.random.default_rng(0)
    for n in (5, 23, 64):
        pat = _sym_pattern(rng, n, 0.1)
        p = fn(pat)
        assert sorted(p.tolist()) == list(range(n))


def test_fill_reduction_beats_natural_on_arrow():
    """Arrowhead matrix: natural order fills completely; MD keeps it sparse."""
    n = 60
    a = np.eye(n)
    a[0, :] = 1.0
    a[:, 0] = 1.0
    pat = CSR.from_dense(a)
    cc_nat = etree_col_counts(pat)
    p = min_degree(pat)
    cc_md = etree_col_counts(pat.permute(p, p))
    assert cc_md.sum() < cc_nat.sum() / 3


def test_select_ordering_picks_min_flops():
    rng = np.random.default_rng(1)
    pat = _sym_pattern(rng, 50, 0.08)
    perm, name, scores = select_ordering(pat, return_all=True)
    flops = {k: v[0] for k, v in scores.items()}
    assert flops[name] == min(flops.values())


def _dense_fill(pat: CSR):
    """Oracle: symbolic Cholesky fill via dense elimination on the pattern."""
    n = pat.n
    a = pat.to_dense() != 0
    l = np.zeros((n, n), dtype=bool)
    for j in range(n):
        struct = a[:, j].copy()
        struct[:j + 1] = False
        l[j, j] = True
        l[struct, j] = True
        rows = np.where(struct)[0]
        for r in rows:
            a[rows, r] = True  # clique fill (symmetric)
            a[r, rows] = True
    return l


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(4, 28), st.floats(0.08, 0.4))
def test_symbolic_matches_dense_oracle(seed, n, density):
    rng = np.random.default_rng(seed)
    pat = _sym_pattern(rng, n, density)
    sym = symbolic_factorize(pat, relax=0, max_super=1, do_supernodes=False)
    l_oracle = _dense_fill(pat)
    for i in range(n):
        got = set(sym.lrow_struct(i).tolist())
        want = set(np.where(l_oracle[i, :i])[0].tolist())
        assert got == want, (i, got, want)
    # column counts consistent
    cc = etree_col_counts(pat)
    assert np.array_equal(cc, l_oracle.sum(axis=0))


def test_supernodes_partition_and_structure():
    rng = np.random.default_rng(2)
    pat = _sym_pattern(rng, 80, 0.15)
    sym = symbolic_factorize(pat, relax=0, max_super=32)
    # partition covers all rows exactly once
    cover = np.zeros(80, dtype=int)
    for t in range(sym.n_nodes):
        s, e = sym.node_rows(t)
        cover[s:e] += 1
    assert np.all(cover == 1)
    # fundamental supernodes: identical U structure beyond the block
    for t in range(sym.n_nodes):
        s, e = sym.node_rows(t)
        if e - s < 2:
            continue
        base = set(sym.urow_struct(e - 1).tolist())
        for j in range(s, e - 1):
            got = set(sym.urow_struct(j).tolist()) - set(range(j + 1, e))
            assert got == base, (t, j)


def test_etree_parent_is_min_struct():
    """parent[j] = min row index in struct(L col j) below j."""
    rng = np.random.default_rng(4)
    pat = _sym_pattern(rng, 40, 0.12)
    parent = etree(pat)
    sym = symbolic_factorize(pat, do_supernodes=False)
    for j in range(40):
        s = sym.urow_struct(j)
        if len(s):
            assert parent[j] == s[0]
        else:
            assert parent[j] == -1


def test_stats_shape():
    rng = np.random.default_rng(5)
    pat = _sym_pattern(rng, 50, 0.1)
    sym = symbolic_factorize(pat)
    st_ = symbolic_stats(sym)
    assert st_["flops"] > 0 and 0 <= st_["supernode_coverage"] <= 1
