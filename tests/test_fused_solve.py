"""Scenario-matrix parity suite for the fused batched solve.

For every scenario × kernel mode × execution path (plain jit vs the
Pallas-TRSM block substitution in interpret mode), the fused on-device
solve_batched — substitution + device CSR residual matvec + the whole
lax.while_loop refinement — must agree with a Python loop of ref-engine
factor+solve to 1e-10, and the two paths' residuals must agree to 1e-10.
"""
import numpy as np
import pytest

from repro.core import CSR, HyluOptions, analyze, factor, solve
from repro.core.api import factor_batched, solve_batched, _solve_batched_hostloop

from tests.helpers import SCENARIOS, scenario_system

MODES = ["rowrow", "hybrid", "supernodal"]
PATHS = ["jit", "pallas-interpret"]
K = 3
N = 30


def _value_sets(Ac, k, seed):
    rng = np.random.default_rng(seed)
    return Ac.data[None, :] * rng.uniform(0.8, 1.2, (k, Ac.nnz))


@pytest.fixture(scope="module")
def fused_case(request):
    """One compiled fused-solve case per (scenario, mode, path) combo."""
    scenario, mode, path = request.param
    Ac, a_sp, b, _ = scenario_system(scenario, n=N, seed=3)
    an = analyze(Ac, HyluOptions(force_mode=mode, engine="jax",
                                 use_pallas=(path == "pallas-interpret")))
    vb = _value_sets(Ac, K, seed=7)
    rng = np.random.default_rng(17)
    bb = rng.normal(size=(K, Ac.n))
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    return scenario, mode, path, Ac, an, vb, bb, bst, x, info


def _ref_loop(an_mode, Ac, vb, bb):
    """Python loop of ref-engine factor + solve over the K value sets."""
    an = analyze(Ac, HyluOptions(force_mode=an_mode, engine="ref"))
    xs, resids = [], []
    for i in range(vb.shape[0]):
        ai = CSR(Ac.n, Ac.indptr, Ac.indices, vb[i].copy())
        st = factor(an, ai, engine="ref")
        x, info = solve(st, bb[i])
        xs.append(x)
        resids.append(info["residual"])
    return np.stack(xs), np.asarray(resids)


ALL_CASES = [(s, m, p) for s in SCENARIOS for m in MODES for p in PATHS]


@pytest.mark.parametrize(
    "fused_case", ALL_CASES, indirect=True,
    ids=[f"{s}-{m}-{p}" for s, m, p in ALL_CASES])
def test_fused_matches_ref_loop(fused_case):
    scenario, mode, path, Ac, an, vb, bb, bst, x, info = fused_case
    assert info["residual"].shape == (K,)
    assert info["residual"].max() < 1e-10, (scenario, mode, path)

    x_ref, resid_ref = _ref_loop(mode, Ac, vb, bb)
    scale = np.abs(x_ref).max() + 1e-30
    assert np.abs(x - x_ref).max() / scale < 1e-10, (scenario, mode, path)
    assert np.abs(info["residual"] - resid_ref).max() < 1e-10, \
        (scenario, mode, path)

    # and the fused program ≡ the host-loop implementation it replaced
    x_host, info_host = _solve_batched_hostloop(bst, bb)
    assert np.abs(x - x_host).max() / scale < 1e-12
    assert np.abs(info["residual"] - info_host["residual"]).max() < 1e-12


@pytest.mark.parametrize(
    "fused_case", [("banded", "hybrid", "jit")], indirect=True,
    ids=["banded-hybrid-jit"])
def test_fused_multi_rhs(fused_case):
    """Multi-RHS (K, n, m) through the same fused program: each column must
    match the single-RHS solve of that column."""
    scenario, mode, path, Ac, an, vb, bb, bst, x, info = fused_case
    rng = np.random.default_rng(5)
    m = 3
    bm = rng.normal(size=(K, Ac.n, m))
    xm, infom = solve_batched(bst, bm)
    assert xm.shape == (K, Ac.n, m)
    assert infom["residual"].shape == (K, m)
    assert infom["residual"].max() < 1e-10
    for j in range(m):
        xj, infoj = solve_batched(bst, bm[:, :, j])
        assert np.abs(xm[:, :, j] - xj).max() < 1e-12
    # the host-loop oracle handles the same multi-RHS shapes
    xh, infoh = _solve_batched_hostloop(bst, bm)
    assert np.abs(xm - xh).max() < 1e-12
    assert np.abs(infom["residual"] - infoh["residual"]).max() < 1e-12
    # broadcast rhs still works
    xb, infob = solve_batched(bst, bb[0])
    assert xb.shape == (K, Ac.n)
    assert infob["residual"].max() < 1e-10


@pytest.mark.parametrize(
    "fused_case", [("circuit", "rowrow", "jit")], indirect=True,
    ids=["circuit-rowrow-jit"])
def test_refine_false_and_zero_rhs(fused_case):
    scenario, mode, path, Ac, an, vb, bb, bst, x, info = fused_case
    x0, info0 = solve_batched(bst, bb, refine=False)
    assert info0["n_refine"] == 0
    assert np.all(info0["n_refine_per_system"] == 0)
    # all-zero rhs: the zero-bnorm guard must not divide by zero, and the
    # solution of A x = 0 is exactly 0
    xz, infoz = solve_batched(bst, np.zeros((K, Ac.n)))
    assert np.all(np.isfinite(infoz["residual"]))
    assert np.abs(xz).max() == 0.0
    assert infoz["residual"].max() == 0.0


@pytest.mark.parametrize(
    "fused_case", [("circuit", "hybrid", "jit")], indirect=True,
    ids=["circuit-hybrid-jit"])
def test_refinement_engaged_parity(fused_case):
    """tol=0 forces the refinement loop to actually iterate until it
    stalls; the fused while_loop and the host-loop oracle follow the same
    per-system acceptance rule.  Their accept/reject decisions sit at the
    round-off floor (device segment-sum vs numpy reduceat residuals), so
    trajectories may differ in which noise-level step they accept — but
    both must genuinely iterate and land on the same solution to full
    refinement accuracy.  tol is a dynamic arg, so this reuses the
    compiled program."""
    scenario, mode, path, Ac, an, vb, bb, bst, x, info = fused_case
    tol_saved = an.opts.refine_tol
    an.opts.refine_tol = 0.0
    try:
        xf, inff = solve_batched(bst, bb, refine=True)
        xh, infh = _solve_batched_hostloop(bst, bb, refine=True)
    finally:
        an.opts.refine_tol = tol_saved
    assert inff["n_refine"] >= 1              # the fused loop really ran
    assert infh["n_refine"] >= 1
    scale = np.abs(xh).max() + 1e-30
    assert np.abs(xf - xh).max() / scale < 1e-12
    assert inff["residual"].max() < 1e-12
    assert infh["residual"].max() < 1e-12
