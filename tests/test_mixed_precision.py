"""Mixed-precision factorization: fp32/bf16 factors + fp64 refinement.

The tentpole contract under test: with ``factor_dtype="float32"`` the
panels and substitution run in reduced precision, but the fused refinement
loop accumulates the residual (against the ORIGINAL fp64 A values) and the
correction in float64 — so the batched solve recovers fp64-accurate
solutions, matching a pure-fp64 oracle to 1e-10 across the scenario matrix.
When refinement stalls (ill-conditioned system where the dtype-scaled pivot
perturbation bites), the per-system escape hatch re-factors and re-solves
exactly the failed subset in float64 and splices the recovery back in, so
callers always get fp64-quality answers or an honest failure mask.
"""
import numpy as np
import pytest

from repro.core import CSR, HyluOptions, analyze, factor, solve
from repro.core.api import (factor_batched, solve_batched, solve_sequence,
                            jax_repeated_engine, plan_fingerprint,
                            pattern_key, resolve_perturb_eps,
                            resolve_refine_tol, resolve_dtype_names,
                            dtype_name, np_dtype)

from tests.helpers import scenario_system, random_system

SCENARIO_MATRIX = ["circuit", "banded", "denseish", "unsym"]
PATHS = ["jit", "pallas-interpret"]
K = 4
N = 40


def _system(scenario):
    if scenario == "unsym":
        Ac, _, b = random_system(N, density=0.15, seed=11)
        return Ac, b
    Ac, _, b, _ = scenario_system(scenario, n=N, seed=3)
    return Ac, b


def _value_sets(Ac, k, seed):
    rng = np.random.default_rng(seed)
    return Ac.data[None, :] * rng.uniform(0.8, 1.2, (k, Ac.nnz))


def _batch(Ac, b, opts):
    an = analyze(Ac, opts)
    vb = _value_sets(Ac, K, seed=7)
    bb = np.random.default_rng(17).normal(size=(K, Ac.n))
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    return an, bst, x, info, vb, bb


# --------------------------------------------------------------------------
# fp32 factor + fp64 refine ≡ fp64 oracle across the scenario matrix
# --------------------------------------------------------------------------
@pytest.mark.parametrize("path", PATHS)
@pytest.mark.parametrize("scenario", SCENARIO_MATRIX)
def test_mixed_fp32_matches_fp64_oracle(scenario, path):
    Ac, b = _system(scenario)
    pallas = path == "pallas-interpret"
    an32, bst32, x32, info32, vb, bb = _batch(
        Ac, b, HyluOptions(engine="jax", use_pallas=pallas,
                           factor_dtype="float32"))
    an64, bst64, x64, info64, _, _ = _batch(
        Ac, b, HyluOptions(engine="jax", use_pallas=pallas))

    # the reduced-precision engine really factored in fp32 ...
    assert np.dtype(bst32.vals.dtype) == np.float32
    assert info32["factor_dtype"] == "float32"
    # ... and still hits the fp64 refinement target without any fallback
    assert info32["residual"].max() < 1e-10, (scenario, path)
    assert not info32["refine_failed"].any(), (scenario, path)
    assert info32["n_fp64_fallback"] == 0
    scale = np.abs(x64).max() + 1e-30
    assert np.abs(x32 - x64).max() / scale < 1e-10, (scenario, path)
    assert np.abs(info32["residual"] - info64["residual"]).max() < 1e-10


def test_mixed_scalar_solve():
    """The scalar analyze→factor→solve path honors factor_dtype too."""
    Ac, b = _system("circuit")
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32"))
    x, info = solve(factor(an, Ac), b)
    assert x.dtype == np.float64
    assert info["residual"] < 1e-10
    assert info["refine_failed"] is False


# --------------------------------------------------------------------------
# stall escape hatch: failed systems re-factored/re-solved in fp64
# --------------------------------------------------------------------------
def _illconditioned_batch(n=24, seed=0):
    """[well, ill, well, ill] dense batch on one pattern.  The ill systems
    have spectrum logspace(0, -5): under the fp32 dtype-scaled perturbation
    threshold (~2.3e-4 of max|M|) their small pivots get perturbed and fp32
    refinement stalls, while the fp64 threshold (1e-8) leaves them alone
    and recovers — exactly the escape-hatch scenario."""
    rng = np.random.default_rng(seed)
    q1, _ = np.linalg.qr(rng.normal(size=(n, n)))
    q2, _ = np.linalg.qr(rng.normal(size=(n, n)))
    ill = q1 @ np.diag(np.logspace(0, -5, n)) @ q2
    well = ill + np.diag(3.0 * np.ones(n))
    indptr = np.arange(0, n * n + 1, n, dtype=np.int64)
    indices = np.tile(np.arange(n, dtype=np.int64), n)
    Ac = CSR(n, indptr, indices, well.reshape(-1).copy())
    vb = np.stack([well.reshape(-1), ill.reshape(-1),
                   well.reshape(-1), ill.reshape(-1)])
    bb = rng.normal(size=(4, n))
    return Ac, vb, bb


def test_stall_escape_hatch_recovers_in_fp64():
    Ac, vb, bb = _illconditioned_batch()
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32"))
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    # exactly the ill systems went through the fp64 redo ...
    assert info["fallback_mask"].tolist() == [False, True, False, True]
    assert info["n_fp64_fallback"] == 2
    assert "fallback_time" in info
    # ... and came back recovered: honest final masks, fp64-quality x
    assert not info["refine_failed"].any()
    assert info["residual"].max() < 1e-10
    for i in range(4):
        a_i = vb[i].reshape(Ac.n, Ac.n)
        x_ref = np.linalg.solve(a_i, bb[i])
        scale = np.abs(x_ref).max() + 1e-30
        assert np.abs(x[i] - x_ref).max() / scale < 1e-8, i


def test_stall_without_fallback_reports_honest_failure():
    Ac, vb, bb = _illconditioned_batch()
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32",
                                 fp64_fallback=False))
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    assert info["refine_failed"].tolist() == [False, True, False, True]
    # stalled ⊆ failed (these systems may exit at max_iter still improving)
    assert not (info["refine_stalled"] & ~info["refine_failed"]).any()
    assert not info["fallback_mask"].any()
    assert info["n_fp64_fallback"] == 0
    # the well systems are still fine; the ill ones sit above the
    # fp64-quality tolerance the mixed path promises (that's the failure)
    tol = resolve_refine_tol(an.opts, "float64")
    assert info["residual"][[0, 2]].max() < tol
    assert info["residual"][[1, 3]].min() > tol


def test_fp64_engine_never_arms_fallback():
    """A pure-fp64 batch on the same ill systems: the fp64 perturbation
    threshold doesn't bite, refinement converges, no fallback machinery."""
    Ac, vb, bb = _illconditioned_batch()
    an = analyze(Ac, HyluOptions(engine="jax"))
    bst = factor_batched(an, Ac, vb)
    x, info = solve_batched(bst, bb)
    assert not info["refine_failed"].any()
    assert not info["refine_stalled"].any()
    assert info["n_fp64_fallback"] == 0
    assert info["residual"].max() < 1e-10


def test_stall_masks_in_sequence_pipeline():
    """The T-step pipeline surfaces per-step failure masks (but leaves the
    fp64 redo to single-step solve_batched — documented behavior)."""
    Ac, vb, bb = _illconditioned_batch()
    x, info = solve_sequence(Ac, [vb, vb], bb,
                             HyluOptions(engine="jax",
                                         factor_dtype="float32"))
    assert info["refine_failed"].shape == (2, 4)
    assert info["refine_failed"].tolist() == [[False, True, False, True]] * 2
    assert info["refine_stalled"].shape == (2, 4)
    assert not (info["refine_stalled"] & ~info["refine_failed"]).any()


# --------------------------------------------------------------------------
# dtype staging parity: the right buffers in the right precision
# --------------------------------------------------------------------------
def test_mixed_engine_staging_dtypes():
    """Mixed path: factors fp32, staged A values/RHS fp64 (the residual
    must see the original-precision values to recover accuracy)."""
    Ac, b = _system("circuit")
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32"))
    eng = jax_repeated_engine(an)
    assert np.dtype(eng.factor_dtype) == np.float32
    assert np.dtype(eng.refine_dtype) == np.float64
    assert np.dtype(eng.values_dtype) == np.float64
    bst = factor_batched(an, Ac, _value_sets(Ac, K, seed=7))
    assert np.dtype(bst.vals.dtype) == np.float32
    assert np.dtype(bst.values_dev.dtype) == np.float64
    assert bst.values_batch.dtype == np.float64
    # halved factor-panel bytes is exactly the memory win the bench records
    assert eng.memory_stats(k=K)["panel_bytes"] * 2 == \
        jax_repeated_engine(an, dtype=np.float64).memory_stats(
            k=K)["panel_bytes"]


def test_pure_fp32_engine_stages_no_float64():
    """refine_dtype="float32" opts out of fp64 accumulation entirely: no
    float64 buffer anywhere on the path (the fp32-serving configuration)."""
    Ac, b = _system("circuit")
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32",
                                 refine_dtype="float32"))
    eng = jax_repeated_engine(an)
    assert np.dtype(eng.values_dtype) == np.float32
    bst = factor_batched(an, Ac, _value_sets(Ac, K, seed=7))
    bb = np.random.default_rng(17).normal(size=(K, Ac.n))
    x, info = solve_batched(bst, bb)
    for buf in (bst.vals, bst.values_dev, bst.values_batch, x):
        assert np.dtype(buf.dtype) == np.float32, buf.dtype
    # the fallback must not arm without fp64-staged values
    assert info["n_fp64_fallback"] == 0 and not info["fallback_mask"].any()
    # fp32 residual floor, fp32 tolerance: a healthy system still converges
    assert info["residual"].max() < resolve_refine_tol(an.opts, "float32")


def test_hostloop_oracle_mixed_parity():
    """The host-loop reference follows the same mixed-precision recipe and
    agrees with the fused loop."""
    from repro.core.api import _solve_batched_hostloop
    Ac, b = _system("banded")
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="float32"))
    bst = factor_batched(an, Ac, _value_sets(Ac, K, seed=7))
    bb = np.random.default_rng(17).normal(size=(K, Ac.n))
    xf, inff = solve_batched(bst, bb)
    xh, infh = _solve_batched_hostloop(bst, bb)
    assert not infh["refine_failed"].any()
    assert not infh["refine_stalled"].any()
    scale = np.abs(xh).max() + 1e-30
    assert np.abs(xf - xh).max() / scale < 1e-10
    assert infh["residual"].max() < 1e-10


# --------------------------------------------------------------------------
# bfloat16 (experimental): usable because the fp64 hatch backstops it
# --------------------------------------------------------------------------
def test_bf16_recovers_via_fallback():
    Ac, b = _system("circuit")
    an = analyze(Ac, HyluOptions(engine="jax", factor_dtype="bfloat16"))
    eng = jax_repeated_engine(an)
    assert dtype_name(eng.factor_dtype) == "bfloat16"
    bst = factor_batched(an, Ac, _value_sets(Ac, K, seed=7))
    bb = np.random.default_rng(17).normal(size=(K, Ac.n))
    x, info = solve_batched(bst, bb)
    assert info["factor_dtype"] == "bfloat16"
    # whether bf16 refinement converged or the hatch fired, the contract is
    # the same: fp64-quality answers and an all-clear failure mask
    assert not info["refine_failed"].any()
    assert info["residual"].max() < 1e-10


def test_panel_eps_underflow_guard():
    """A positive perturbation threshold that underflows to zero in the
    panel dtype is clamped to the smallest normal (bf16 underflows near
    1e-38); an exactly-zero eps (perturbation off) stays zero."""
    import jax.numpy as jnp
    from repro.kernels.panel.ops import _eps_in
    assert float(_eps_in(jnp.bfloat16, 1e-30)) > 0.0
    assert float(_eps_in(jnp.float32, 1e-42)) > 0.0
    assert float(_eps_in(jnp.bfloat16, 0.0)) == 0.0
    assert float(_eps_in(jnp.float32, 1e-4)) == np.float32(1e-4)


# --------------------------------------------------------------------------
# fingerprints + dtype-aware option resolution
# --------------------------------------------------------------------------
def test_factor_dtype_is_plan_affecting_refine_knobs_are_not():
    Ac, b = _system("circuit")
    base = plan_fingerprint(Ac, HyluOptions())
    fp32 = plan_fingerprint(Ac, HyluOptions(factor_dtype="float32"))
    bf16 = plan_fingerprint(Ac, HyluOptions(factor_dtype="bfloat16"))
    assert len({base, fp32, bf16}) == 3
    # the pattern address is dtype-independent — one symbolic analysis
    assert pattern_key(Ac) == pattern_key(Ac)
    an32 = analyze(Ac, HyluOptions(factor_dtype="float32"))
    an64 = analyze(Ac, HyluOptions())
    assert an32.pattern_key == an64.pattern_key
    assert an32.fingerprint != an64.fingerprint
    # runtime-only mixed-precision knobs share the fingerprint
    for o in (HyluOptions(refine_dtype="float32"),
              HyluOptions(fp64_fallback=False),
              HyluOptions(refine_tol=1e-9)):
        assert plan_fingerprint(Ac, o) == base, o
    # the None perturb_eps default fingerprints like its fp64 literal
    assert plan_fingerprint(Ac, HyluOptions(perturb_eps=1e-8)) == base
    assert plan_fingerprint(Ac, HyluOptions(perturb_eps=1e-6)) != base


def test_dtype_aware_option_resolution():
    eps64, eps32 = np.finfo(np.float64).eps, np.finfo(np.float32).eps
    assert resolve_perturb_eps(HyluOptions()) == 1e-8
    assert resolve_refine_tol(HyluOptions()) == 1e-12
    o32 = HyluOptions(factor_dtype="float32")
    assert np.isclose(resolve_perturb_eps(o32),
                      1e-8 * np.sqrt(eps32 / eps64))
    assert np.isclose(resolve_refine_tol(o32, "float32"),
                      1e-12 * (eps32 / eps64))
    # the mixed path resolves the tol against the REFINE dtype → still the
    # fp64-quality promise
    assert resolve_refine_tol(o32, "float64") == 1e-12
    # explicit overrides are honored verbatim, old-literal semantics intact
    assert resolve_perturb_eps(HyluOptions(perturb_eps=1e-6)) == 1e-6
    assert resolve_refine_tol(HyluOptions(refine_tol=0.0)) == 0.0
    assert resolve_refine_tol(HyluOptions(refine_tol=0.0), "float32") == 0.0
    # dtype plumbing helpers
    assert resolve_dtype_names(o32, x64_enabled=True) == \
        ("float32", "float64")
    assert resolve_dtype_names(o32, x64_enabled=False) == \
        ("float32", "float32")
    assert resolve_dtype_names(
        HyluOptions(factor_dtype="float32", refine_dtype="float32"),
        x64_enabled=True) == ("float32", "float32")
    assert np_dtype("float32") == np.float32
    assert np_dtype("bfloat16").itemsize == 2
    with pytest.raises(ValueError, match="unsupported factor/refine dtype"):
        dtype_name("float16")
