"""Distributed substrate tests: checkpoint roundtrip + elastic restore,
trainer fault tolerance, gradient compression, data determinism, sharding
spec validity, roofline parser vs XLA cost_analysis."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import SyntheticLM, MemmapDataset, write_synthetic_corpus
from repro.optim import adamw
from repro.optim.compression import CompressionConfig, compress_grads, init_error_state


# ------------------------------------------------------------- checkpointer
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    tree = dict(a=jnp.arange(12.0).reshape(3, 4),
                b=dict(c=jnp.ones((5,), jnp.int32)))
    ck.save(3, tree)
    ck.save(7, jax.tree.map(lambda x: x * 2, tree))
    assert ck.committed_steps() == [3, 7]
    restored = ck.restore(7, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 2)


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2, async_save=False)
    t = dict(x=jnp.zeros(3))
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    assert ck.committed_steps() == [3, 4]


def test_checkpoint_uncommitted_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=3, async_save=False)
    t = dict(x=jnp.zeros(3))
    ck.save(5, t)
    # simulate crash mid-save: directory without COMMIT
    os.makedirs(tmp_path / "step_000000009/arrays")
    assert ck.latest_step() == 5


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore applies new shardings (elastic resume on a different mesh)."""
    ck = Checkpointer(str(tmp_path), async_save=False)
    t = dict(w=jnp.arange(16.0).reshape(4, 4))
    ck.save(1, t)
    from repro.launch.mesh import compat_make_mesh
    mesh = compat_make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = dict(w=NamedSharding(mesh, P("data", None)))
    restored = ck.restore(1, t, shardings=sh)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(t["w"]))


# ------------------------------------------------------------------ trainer
def test_trainer_resume_identical_stream(tmp_path):
    """Restart-from-checkpoint replays the same data: loss trajectory of a
    30-step run == 20 steps + resume + 10 steps."""
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = registry.get("musicgen-medium").reduced()
    # musicgen embeds-input complicates batches; use tokens-only arch
    cfg = registry.get("gemma-7b").reduced()
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=5)

    def mk(ckdir):
        params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
        return Trainer(TrainerConfig(total_steps=30, ckpt_every=10,
                                     ckpt_dir=str(ckdir), log_every=1000,
                                     seq_chunk=16),
                       cfg, params, data)

    t1 = mk(tmp_path / "a")
    log1 = t1.run()
    t2 = mk(tmp_path / "b")
    t2.run(n_steps=20)
    t2.ckpt.wait()
    t3 = mk(tmp_path / "b")
    assert t3.maybe_resume() == 20
    log3 = t3.run()
    l1 = [r["loss"] for r in log1][-5:]
    l3 = [r["loss"] for r in log3][-5:]
    np.testing.assert_allclose(l1, l3, rtol=1e-4)


def test_trainer_loss_decreases():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.train.trainer import Trainer, TrainerConfig
    cfg = registry.get("phi3-medium-14b").reduced()
    params = T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=1)
    tr = Trainer(TrainerConfig(total_steps=40, ckpt_every=10**9,
                               log_every=10**9, seq_chunk=32),
                 cfg, params, data,
                 opt_cfg=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                           total_steps=40))
    log = tr.run()
    first = np.mean([r["loss"] for r in log[:5]])
    last = np.mean([r["loss"] for r in log[-5:]])
    assert last < first - 0.2, (first, last)


# -------------------------------------------------------------- compression
@pytest.mark.parametrize("kind", ["bf16", "int8"])
def test_compression_error_feedback(kind):
    cfg = CompressionConfig(kind=kind, error_feedback=True)
    rng = np.random.default_rng(0)
    g_true = dict(w=jnp.asarray(rng.normal(size=(64, 64)), jnp.float32))
    err = init_error_state(g_true, cfg)
    # accumulated compressed grads ≈ accumulated true grads (EF property)
    acc_c = np.zeros((64, 64))
    for _ in range(20):
        gc, err = compress_grads(cfg, g_true, err)
        acc_c += np.asarray(gc["w"], np.float64)
    acc_t = np.asarray(g_true["w"], np.float64) * 20
    rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
    assert rel < 0.02, rel


def test_compression_none_passthrough():
    cfg = CompressionConfig(kind="none")
    g = dict(w=jnp.ones((4,)))
    gc, err = compress_grads(cfg, g, None)
    assert gc["w"] is g["w"]


# --------------------------------------------------------------------- data
def test_data_determinism():
    d = SyntheticLM(vocab=100, seq_len=16, global_batch=4, seed=9)
    b1, b2 = d.batch(42), d.batch(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(43)["tokens"], b1["tokens"])


def test_memmap_dataset(tmp_path):
    path = str(tmp_path / "corpus.bin")
    write_synthetic_corpus(path, 10_000, vocab=50, seed=0)
    d = MemmapDataset(path, vocab=50, seq_len=32, global_batch=4, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# -------------------------------------------------- sharding specs validity
def test_param_specs_cover_all_archs():
    from repro.configs import registry
    from repro.models import transformer as T
    from repro.models import sharding as Sh
    for name, cfg in registry.ARCHS.items():
        shapes = jax.eval_shape(
            lambda k, c=cfg: T.init_params(c, k, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        specs = Sh.param_specs(cfg, shapes)
        flat_sh, _ = jax.tree_util.tree_flatten(shapes)
        flat_sp, _ = jax.tree_util.tree_flatten(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert len(flat_sh) == len(flat_sp), name
        for sh, sp in zip(flat_sh, flat_sp):
            assert len(sp) <= len(sh.shape), (name, sh.shape, sp)


# ------------------------------------------------------------ roofline/HLO
def test_hlo_cost_matches_xla_flat():
    from repro.roofline import hlo_cost

    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 16), jnp.float32)).compile()
    mine = hlo_cost.analyze(c.as_text())
    xla = c.cost_analysis()
    if isinstance(xla, (list, tuple)):        # older jax returns [dict]
        xla = xla[0]
    assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.05
    assert abs(mine.bytes_accessed - xla["bytes accessed"]) \
        / xla["bytes accessed"] < 0.05


def test_hlo_cost_multiplies_scan_trip_count():
    from repro.roofline import hlo_cost

    def f(x, w):
        def body(c, _):
            return jnp.dot(c, w), ()
        c2, _ = jax.lax.scan(body, x, None, length=11)
        return c2.sum()

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((32, 32), jnp.float32),
                         jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    mine = hlo_cost.analyze(c.as_text())
    expect = 11 * 2 * 32 * 32 * 32
    assert 0.9 < mine.flops / expect < 1.2


# ---------------------------------------------------------- dry-run (smoke)
@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    """Full dryrun machinery on a 16-device fake mesh in a subprocess
    (device count must be set before jax init)."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from repro.configs import registry
from repro.configs.shapes import ShapeCfg
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import compat_make_mesh
mesh = compat_make_mesh((4, 4), ("data", "model"))
cfg = registry.get("phi3-medium-14b").reduced()
shape = ShapeCfg("smoke", 64, 8, "train")
rec = lower_cell(cfg, shape, mesh, "mesh4x4", seq_chunk=32)
assert rec["status"] == "ok", rec
shape_d = ShapeCfg("smoke_d", 64, 8, "decode")
rec = lower_cell(cfg, shape_d, mesh, "mesh4x4")
assert rec["status"] == "ok", rec
print("DRYRUN_SMOKE_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "DRYRUN_SMOKE_OK" in r.stdout, r.stderr[-2000:]
