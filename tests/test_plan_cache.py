"""Plan-cache suite: content-addressed fingerprints, LRU + hit/miss
semantics, the analyze(reuse=) pattern-fingerprint validation, and the
disk persistence round trip (in-process and across a fresh subprocess,
bit-identical solves — observed 0.0 like test_sharding.py)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import CSR, HyluOptions, analyze
from repro.core.api import (factor, factor_batched, solve, solve_batched,
                            pattern_key, plan_fingerprint)
from repro.core.plan_cache import (PlanCache, PlanCacheFormatError,
                                   FORMAT_VERSION, DEFAULT_CACHE_DIR,
                                   default_cache_root, resolve_cache_dir,
                                   save_analysis, load_analysis)

from tests.helpers import scenario_system


def _case(name="circuit", n=40, seed=0, k=3):
    Ac, _, b, _ = scenario_system(name, n=n, seed=seed)
    rng = np.random.default_rng(seed + 7)
    vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (k, Ac.nnz))
    bb = rng.normal(size=(k, Ac.n))
    return Ac, vb, bb


# --------------------------------------------------------------------------
# fingerprints
# --------------------------------------------------------------------------
def test_fingerprint_deterministic_and_content_addressed():
    Ac, _, _ = _case()
    assert pattern_key(Ac) == pattern_key((Ac.indptr, Ac.indices))
    assert plan_fingerprint(Ac, HyluOptions()) == \
        plan_fingerprint(Ac, HyluOptions())
    # same pattern, different values → same address (values are not content)
    A2 = CSR(Ac.n, Ac.indptr, Ac.indices, Ac.data * 2.0)
    assert plan_fingerprint(A2, HyluOptions()) == \
        plan_fingerprint(Ac, HyluOptions())
    # different pattern → different address
    B, _, _ = _case("banded")
    assert plan_fingerprint(B, HyluOptions()) != \
        plan_fingerprint(Ac, HyluOptions())


def test_fingerprint_distinct_per_plan_affecting_option():
    """Differing kernel modes / plan options are distinct cache entries;
    runtime-only knobs (engine/mesh/donate/refine) are not."""
    Ac, _, _ = _case()
    base = plan_fingerprint(Ac, HyluOptions())
    distinct = [HyluOptions(force_mode="rowrow"),
                HyluOptions(force_mode="hybrid"),
                HyluOptions(force_mode="supernodal"),
                HyluOptions(relax=2), HyluOptions(max_super=16),
                HyluOptions(orderings=("natural",)),
                HyluOptions(perturb_eps=1e-6),
                HyluOptions(bulk_min_width=4),
                HyluOptions(factor_schedule="unrolled"),
                HyluOptions(use_pallas=True),
                HyluOptions(amalg_fill_tol=0.3)]
    fps = [plan_fingerprint(Ac, o) for o in distinct]
    assert len({base, *fps}) == len(distinct) + 1
    same = [HyluOptions(engine="jax"), HyluOptions(mesh=1),
            HyluOptions(donate=True), HyluOptions(refine_max_iter=9),
            HyluOptions(refine_tol=1e-9),
            HyluOptions(cache_root="/tmp/elsewhere")]
    for o in same:
        assert plan_fingerprint(Ac, o) == base, o


def test_analysis_carries_fingerprint():
    Ac, _, _ = _case()
    opts = HyluOptions()
    an = analyze(Ac, opts)
    assert an.pattern_key == pattern_key(Ac)
    assert an.fingerprint == plan_fingerprint(Ac, opts)


# --------------------------------------------------------------------------
# analyze(reuse=) validation (the silently-wrong-factors bugfix)
# --------------------------------------------------------------------------
def test_reuse_pattern_mismatch_raises():
    Ac, _, _ = _case("circuit")
    B, _, _ = _case("banded")
    an = analyze(Ac)
    with pytest.raises(ValueError, match="different sparsity pattern"):
        analyze(B, reuse=an)


def test_reuse_same_pattern_still_works():
    """The documented reuse flow — same matrix, different kernel mode —
    must keep working and still solve correctly."""
    Ac, _, _ = _case("circuit")
    an = analyze(Ac)
    an2 = analyze(Ac, HyluOptions(force_mode="hybrid"), reuse=an)
    assert an2.choice.mode == "hybrid"
    assert an2.p is an.p                      # ordering actually reused
    rng = np.random.default_rng(3)
    b = rng.normal(size=Ac.n)
    x, info = solve(factor(an2, Ac), b)
    assert info["residual"] < 1e-10


# --------------------------------------------------------------------------
# cache semantics
# --------------------------------------------------------------------------
def test_memory_hit_returns_same_analysis_and_skips_analyze(tmp_path):
    Ac, vb, bb = _case()
    cache = PlanCache(directory=str(tmp_path))
    an = cache.get_or_analyze(Ac, HyluOptions())
    assert cache.stats["misses"] == 1 and cache.stats["analyze_calls"] == 1
    an2 = cache.get_or_analyze(Ac, HyluOptions())
    assert an2 is an                          # same object ⇒ shared jit cache
    assert cache.stats["hits"] == 1
    assert cache.stats["analyze_calls"] == 1  # the analyze phase was skipped


def test_memory_hit_honors_callers_runtime_options(tmp_path):
    """Runtime-only knobs (engine/mesh/donate/refine) share a fingerprint,
    but a hit must come back bound to the CALLER's options — same shared
    plan arrays and jit_cache, different opts view (consistent with the
    disk-hit path, which loads under the caller's opts)."""
    Ac, _, _ = _case()
    cache = PlanCache(directory=str(tmp_path))
    an = cache.get_or_analyze(Ac, HyluOptions())
    o2 = HyluOptions(engine="jax", refine_tol=1e-3, refine_max_iter=0)
    an2 = cache.get_or_analyze(Ac, o2)
    assert cache.stats["hits"] == 1 and cache.stats["analyze_calls"] == 1
    assert an2.opts is o2                      # caller's runtime config wins
    assert an.opts.refine_tol is None          # first caller's view intact
    assert an2.fingerprint == an.fingerprint
    assert an2.plan is an.plan                 # artifact shared, not copied
    assert an2.jit_cache is an.jit_cache       # compiled engines shared


def test_corrupt_artifact_falls_back_to_analyze(tmp_path):
    """A truncated/non-zip file at the artifact path (disk corruption) is
    a miss, not a crash."""
    Ac, _, _ = _case()
    cache = PlanCache(directory=str(tmp_path))
    fp = cache.fingerprint(Ac, HyluOptions())
    os.makedirs(str(tmp_path), exist_ok=True)
    with open(cache.path_for(fp), "wb") as f:
        f.write(b"PK\x03\x04 truncated garbage")
    with pytest.raises(PlanCacheFormatError):
        load_analysis(cache.path_for(fp))
    an = cache.get_or_analyze(Ac, HyluOptions())
    assert an.fingerprint == fp
    assert cache.stats["analyze_calls"] == 1 and cache.stats["disk_hits"] == 0


def test_distinct_options_are_distinct_entries(tmp_path):
    Ac, _, _ = _case()
    cache = PlanCache(directory=str(tmp_path))
    an_r = cache.get_or_analyze(Ac, HyluOptions(force_mode="rowrow"))
    an_h = cache.get_or_analyze(Ac, HyluOptions(force_mode="hybrid"))
    assert an_r is not an_h
    assert an_r.fingerprint != an_h.fingerprint
    assert len(cache) == 2 and cache.stats["analyze_calls"] == 2


def test_lru_eviction(tmp_path):
    cache = PlanCache(capacity=2, directory=None)
    mats = [_case(name, n=36)[0]
            for name in ("circuit", "banded", "denseish")]
    fps = [cache.get_or_analyze(a, HyluOptions()).fingerprint for a in mats]
    assert len(cache) == 2 and cache.stats["evictions"] == 1
    assert fps[0] not in cache                # oldest evicted
    assert fps[1] in cache and fps[2] in cache
    cache.get_or_analyze(mats[1], HyluOptions())   # refresh recency of [1]
    cache.get_or_analyze(mats[0], HyluOptions())   # re-analyze [0] → evict [2]
    assert fps[2] not in cache and fps[1] in cache


def test_no_directory_means_no_disk(tmp_path):
    Ac, _, _ = _case()
    cache = PlanCache(directory=None)
    cache.get_or_analyze(Ac, HyluOptions())
    assert cache.stats["saves"] == 0
    assert cache.path_for("deadbeef") is None


# --------------------------------------------------------------------------
# disk persistence
# --------------------------------------------------------------------------
def test_disk_round_trip_bit_identical_solve(tmp_path):
    Ac, vb, bb = _case("circuit", n=48, k=4)
    opts = HyluOptions()
    cache = PlanCache(directory=str(tmp_path))
    an = cache.get_or_analyze(Ac, opts)
    x0, info0 = solve_batched(factor_batched(an, Ac, vb), bb)

    fresh = PlanCache(directory=str(tmp_path))
    an2 = fresh.get_or_analyze(Ac, opts)
    assert fresh.stats["disk_hits"] == 1
    assert fresh.stats["analyze_calls"] == 0   # host analyze phase skipped
    assert "load" in an2.timings and "matching" not in an2.timings
    # the loaded artifact is structurally equal…
    np.testing.assert_array_equal(an2.p, an.p)
    np.testing.assert_array_equal(an2.q, an.q)
    np.testing.assert_array_equal(an2.src_map, an.src_map)
    np.testing.assert_array_equal(an2.scale_map, an.scale_map)
    np.testing.assert_array_equal(an2.plan.a_scatter, an.plan.a_scatter)
    assert an2.choice.mode == an.choice.mode
    assert [len(nd.edges) for nd in an2.plan.nodes] == \
        [len(nd.edges) for nd in an.plan.nodes]
    # …and solves bit-identically (asserted ≤1e-10, observed 0.0)
    x1, info1 = solve_batched(factor_batched(an2, Ac, vb), bb)
    assert np.abs(x1 - x0).max() <= 1e-10
    assert np.abs(info1["residual"] - info0["residual"]).max() <= 1e-10
    assert np.abs(x1 - x0).max() == 0.0


@pytest.mark.parametrize("name", ["banded", "denseish"])
def test_disk_round_trip_other_scenarios(tmp_path, name):
    Ac, vb, bb = _case(name, n=36, k=2)
    opts = HyluOptions()
    an = analyze(Ac, opts)
    path = save_analysis(an, str(tmp_path / "art.npz"))
    an2 = load_analysis(path, opts=opts, expected_fingerprint=an.fingerprint)
    x0, _ = solve_batched(factor_batched(an, Ac, vb), bb)
    x1, _ = solve_batched(factor_batched(an2, Ac, vb), bb)
    assert np.abs(x1 - x0).max() == 0.0


def test_version_and_fingerprint_guards(tmp_path):
    Ac, _, _ = _case()
    opts = HyluOptions()
    an = analyze(Ac, opts)
    path = save_analysis(an, str(tmp_path / "art.npz"))
    with pytest.raises(PlanCacheFormatError, match="does not match"):
        load_analysis(path, opts=opts, expected_fingerprint="0" * 64)
    with pytest.raises(PlanCacheFormatError, match="plan options"):
        load_analysis(path, opts=HyluOptions(force_mode="hybrid"))
    # tamper the version: the cache must fall back to a clean re-analyze
    z = np.load(path, allow_pickle=False)
    meta = json.loads(str(z["meta"][()]))
    meta["format_version"] = FORMAT_VERSION + 1
    arrays = {name: z[name] for name in z.files if name != "meta"}
    fp = an.fingerprint
    bad_path = str(tmp_path / f"{fp}.npz")
    np.savez_compressed(bad_path, meta=json.dumps(meta), **arrays)
    with pytest.raises(PlanCacheFormatError, match="format version"):
        load_analysis(bad_path, opts=opts)
    cache = PlanCache(directory=str(tmp_path))
    an2 = cache.get_or_analyze(Ac, opts)      # untrusted file → re-analyze
    assert cache.stats["analyze_calls"] == 1
    assert cache.stats["disk_hits"] == 0
    assert an2.fingerprint == fp


def test_invalidate(tmp_path):
    Ac, _, _ = _case()
    cache = PlanCache(directory=str(tmp_path))
    an = cache.get_or_analyze(Ac, HyluOptions())
    fp = an.fingerprint
    cache.invalidate(fp, disk=True)
    assert fp not in cache
    assert not os.path.exists(cache.path_for(fp))
    cache.get_or_analyze(Ac, HyluOptions())
    assert cache.stats["analyze_calls"] == 2


# --------------------------------------------------------------------------
# fresh-process round trip: save here, reload + solve in a subprocess,
# compare the solution byte-for-byte
# --------------------------------------------------------------------------
_SUBPROCESS_CODE = """
import numpy as np
import jax
jax.config.update("jax_enable_x64", True)
import sys
sys.path.insert(0, "tests")
from helpers import scenario_system
from repro.core import HyluOptions
from repro.core.api import factor_batched, solve_batched
from repro.core.plan_cache import PlanCache

Ac, _, _, _ = scenario_system("circuit", n=48, seed=0)
rng = np.random.default_rng(7)
vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (4, Ac.nnz))
bb = rng.normal(size=(4, Ac.n))
cache = PlanCache(directory={cache_dir!r})
an = cache.get_or_analyze(Ac, HyluOptions())
assert cache.stats["disk_hits"] == 1, cache.stats
assert cache.stats["analyze_calls"] == 0, cache.stats   # analyze skipped
x, info = solve_batched(factor_batched(an, Ac, vb), bb)
print("XHASH", x.tobytes().hex()[:64], np.abs(x).sum())
print("SUBPROCESS_PLAN_CACHE_OK")
"""


def test_persistence_round_trip_subprocess(tmp_path):
    """save → reload in a fresh subprocess → bit-identical solve, with the
    analyze phase skipped (counter-asserted)."""
    Ac, _, _, _ = scenario_system("circuit", n=48, seed=0)
    rng = np.random.default_rng(7)
    vb = Ac.data[None, :] * rng.uniform(0.9, 1.1, (4, Ac.nnz))
    bb = rng.normal(size=(4, Ac.n))
    cache = PlanCache(directory=str(tmp_path))
    an = cache.get_or_analyze(Ac, HyluOptions())
    x0, _ = solve_batched(factor_batched(an, Ac, vb), bb)

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c",
         _SUBPROCESS_CODE.format(cache_dir=str(tmp_path))],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "SUBPROCESS_PLAN_CACHE_OK" in r.stdout, (r.stdout[-2000:],
                                                    r.stderr[-4000:])
    xhash = [ln for ln in r.stdout.splitlines()
             if ln.startswith("XHASH")][0].split()[1]
    assert xhash == x0.tobytes().hex()[:64]    # byte-for-byte identical


# ---------------------------------------------------------------------------
# cache-root resolution (the CWD-relative-path fix)

def test_cache_dir_resolution(tmp_path, monkeypatch):
    """The 'auto' directory sentinel never resolves relative to the CWD:
    explicit paths and None pass through untouched; HyluOptions.cache_root
    wins over $HYLU_CACHE_ROOT, which wins over the package-derived
    default — and the default is absolute regardless of os.getcwd()."""
    assert resolve_cache_dir(None) is None
    assert resolve_cache_dir("some/dir") == "some/dir"
    # options-level root beats the environment
    monkeypatch.setenv("HYLU_CACHE_ROOT", str(tmp_path / "env"))
    got = resolve_cache_dir(DEFAULT_CACHE_DIR, cache_root=str(tmp_path / "o"))
    assert got == str(tmp_path / "o" / "plan_cache")
    assert resolve_cache_dir(DEFAULT_CACHE_DIR) == \
        str(tmp_path / "env" / "plan_cache")
    # with no overrides the root is absolute and CWD-independent
    monkeypatch.delenv("HYLU_CACHE_ROOT")
    monkeypatch.chdir(tmp_path)
    root = default_cache_root()
    assert os.path.isabs(root)
    assert str(tmp_path) not in root


def test_plan_cache_honors_cache_root(tmp_path):
    """A PlanCache built with the sentinel + an explicit cache_root writes
    its artifacts under <root>/plan_cache, not under the CWD."""
    cache = PlanCache(directory=DEFAULT_CACHE_DIR,
                      cache_root=str(tmp_path / "store"))
    assert cache.directory == str(tmp_path / "store" / "plan_cache")
    Ac, _, _, _ = scenario_system("circuit", n=32, seed=1)
    cache.get_or_analyze(Ac, HyluOptions())
    assert os.path.isdir(cache.directory)
    assert os.listdir(cache.directory)         # artifact persisted there
