"""Per-Pallas-kernel shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

RNG = np.random.default_rng(42)


def _tri(k, dtype):
    u = RNG.normal(size=(k, k))
    return jnp.asarray(np.triu(u) + 3 * np.eye(k), dtype)


TRISOLVE_SHAPES = [(1, 3), (5, 8), (17, 13), (40, 32), (3, 1)]


@pytest.mark.parametrize("nr,k", TRISOLVE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_trisolve(nr, k, dtype):
    from repro.kernels.trisolve import ops
    from repro.kernels.trisolve.ref import trsm_upper_ref
    u = _tri(k, dtype)
    x = jnp.asarray(RNG.normal(size=(nr, k)), dtype)
    y = ops.trsm(u, x)
    yr = trsm_upper_ref(u, x)
    tol = 1e-10 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("kb,nr,k", [(1, 5, 8), (4, 17, 13), (6, 3, 1)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_trisolve_batched(kb, nr, k, dtype):
    """Batched TRSM (repeated-solve path): K solves, one pallas program."""
    from repro.kernels.trisolve import ops
    from repro.kernels.trisolve.ref import trsm_upper_ref_batched
    u = jnp.stack([_tri(k, dtype) for _ in range(kb)])
    x = jnp.asarray(RNG.normal(size=(kb, nr, k)), dtype)
    y = ops.trsm_batched(u, x)
    yr = trsm_upper_ref_batched(u, x)
    tol = 1e-10 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("kb,k,m", [(1, 5, 1), (4, 13, 3), (3, 8, 6),
                                    (2, 2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_trisolve_left_solves(kb, k, m, dtype):
    """The engine's block-substitution left-solves (U w = b and unit-L
    w = b) expressed on the right-solve Pallas kernel via transpose/flip,
    vs direct dense solves."""
    from repro.kernels.trisolve import ops
    blk = np.stack([np.asarray(_tri(k, jnp.float64))
                    + np.tril(RNG.normal(size=(k, k)), -1)
                    for _ in range(kb)])
    b = RNG.normal(size=(kb, k, m))
    blk_j = jnp.asarray(blk, dtype)
    b_j = jnp.asarray(b, dtype)
    tol = 1e-10 if dtype == jnp.float64 else 1e-3
    w_u = np.asarray(ops.trsm_left_upper_batched(blk_j, b_j))
    w_l = np.asarray(ops.trsm_left_unit_lower_batched(blk_j, b_j))
    wr_u = np.asarray(ops.trsm_left_upper_ref_batched(blk_j, b_j))
    wr_l = np.asarray(ops.trsm_left_unit_lower_ref_batched(blk_j, b_j))
    for i in range(kb):
        u = np.triu(blk[i])
        l = np.tril(blk[i], -1) + np.eye(k)
        np.testing.assert_allclose(w_u[i], np.linalg.solve(u, b[i]),
                                   atol=tol, rtol=tol)
        np.testing.assert_allclose(w_l[i], np.linalg.solve(l, b[i]),
                                   atol=tol, rtol=tol)
    np.testing.assert_allclose(w_u, wr_u, atol=tol, rtol=tol)
    np.testing.assert_allclose(w_l, wr_l, atol=tol, rtol=tol)


SUPSUP_SHAPES = [(5, 3, 7), (16, 8, 40), (33, 13, 5), (2, 1, 3), (8, 8, 128)]


@pytest.mark.parametrize("nr,k,m", SUPSUP_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_supsup(nr, k, m, dtype):
    from repro.kernels.supsup import ops
    from repro.kernels.supsup.ref import supsup_update_ref
    x = jnp.asarray(RNG.normal(size=(nr, k + m)), dtype)
    src = jnp.asarray(RNG.normal(size=(k, k + m)), dtype)
    src = src.at[:, :k].set(_tri(k, dtype))
    lts, xr = ops.supsup_update(x, src, k)
    ltr, xrr = supsup_update_ref(x, src, k)
    tol = 1e-10 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(lts), np.asarray(ltr), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xrr), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("k,m", [(3, 7), (8, 40), (13, 5), (1, 9), (32, 200)])
@pytest.mark.parametrize("dtype", [jnp.float64, jnp.float32])
def test_suprow(k, m, dtype):
    from repro.kernels.suprow import ops
    from repro.kernels.suprow.ref import suprow_update_ref
    x = jnp.asarray(RNG.normal(size=(k + m,)), dtype)
    src = jnp.asarray(RNG.normal(size=(k, k + m)), dtype)
    src = src.at[:, :k].set(_tri(k, dtype))
    y, xr = ops.suprow_update(x, src, k)
    yr, xrr = suprow_update_ref(x, src, k)
    tol = 1e-10 if dtype == jnp.float64 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xrr), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("nr,ls,us", [(4, 2, 3), (16, 5, 9), (1, 0, 4),
                                      (8, 0, 0), (32, 7, 40)])
def test_panel_lu(nr, ls, us):
    from repro.kernels.panel import ops
    from repro.kernels.panel.ref import panel_lu_ref
    w = ls + nr + us
    p = jnp.asarray(RNG.normal(size=(nr, w)))
    o, pm, nper = ops.panel_lu(p, nr, ls, 1e-10)
    orf, pmr, nperr = panel_lu_ref(p, nr, ls, jnp.asarray(1e-10))
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-11)
    assert np.array_equal(np.asarray(pm), np.asarray(pmr))
    assert int(nper) == int(nperr)


def test_panel_lu_perturbation_counts():
    from repro.kernels.panel import ops
    p = jnp.zeros((4, 6)).at[:, 1:5].set(jnp.eye(4) * 1e-30)
    p = p.at[0, 1].set(2.0)
    o, pm, nper = ops.panel_lu(p, 4, 1, 1e-8)
    assert int(nper) == 3          # three tiny pivots perturbed


FLASH_CASES = [(2, 4, 2, 64, 32, True), (1, 8, 8, 96, 64, True),
               (2, 4, 1, 40, 16, True), (1, 2, 2, 50, 32, False),
               (1, 4, 4, 130, 64, True)]


@pytest.mark.parametrize("b,hq,hkv,t,d,causal", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, hq, hkv, t, d, causal, dtype):
    from repro.kernels.flashattn.kernel import flash_attention
    from repro.kernels.flashattn.ref import attention_ref
    q = jnp.asarray(RNG.normal(size=(b, hq, t, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), dtype)
    o = flash_attention(q, k, v, bq=32, bk=32, causal=causal)
    orf = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(orf),
                               atol=tol, rtol=tol)


def test_flash_matches_chunked_xla():
    """The pure-XLA chunked attention and the Pallas kernel agree."""
    from repro.kernels.flashattn.kernel import flash_attention
    from repro.models.layers import _chunked_causal_attention
    b, h, hkv, t, d = 2, 4, 2, 96, 32
    q = jnp.asarray(RNG.normal(size=(b, t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, t, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, t, hkv, d)), jnp.float32)
    o_xla = _chunked_causal_attention(q, k, v, chunk_k=32)
    o_pl = flash_attention(jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
                           jnp.moveaxis(v, 2, 1), bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_xla),
                               np.asarray(jnp.moveaxis(o_pl, 1, 2)),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("bh,t,hs,bt", [(4, 64, 16, 16), (2, 100, 32, 32),
                                        (6, 33, 8, 16), (1, 256, 64, 64)])
def test_wkv_kernel(bh, t, hs, bt):
    """RWKV6 WKV recurrence: VMEM-resident-state kernel vs scan oracle."""
    from repro.kernels.wkv.ops import wkv_padded
    from repro.kernels.wkv.ref import wkv_ref
    r = jnp.asarray(RNG.normal(size=(bh, t, hs)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(bh, t, hs)), jnp.float32) * 0.3
    v = jnp.asarray(RNG.normal(size=(bh, t, hs)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.7, 0.999, size=(bh, t, hs)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(bh, hs)) * 0.3, jnp.float32)
    y = wkv_padded(r, k, v, w, u, bt=bt)
    yr, _ = wkv_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-4,
                               rtol=2e-4)
