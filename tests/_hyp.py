"""Hypothesis import shim.

Uses the real ``hypothesis`` package when it is installed (CI installs it
via the ``test`` extra in pyproject.toml).  In minimal environments the
property tests fall back to a deterministic fixed-seed random search over
the same strategy ranges, so the suite always collects and the properties
are still exercised — just without shrinking or example databases.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _StrategiesModule:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(len(elements)))])

    st = _StrategiesModule()

    def given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(getattr(wrapper, "_max_examples", 20)):
                    fn(*[s.draw(rng) for s in strategies])
            # hide the wrapped signature: the strategy args are filled by the
            # shim, so pytest must not mistake them for fixtures
            del wrapper.__wrapped__
            wrapper._max_examples = 20
            return wrapper
        return decorate

    def settings(max_examples=20, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate
