"""SolverService suite: the mixed-pattern serving dispatcher.

Covers: an interleaved stream of ≥3 distinct sparsity patterns solved
through one service with per-request results bit-identical to dispatching
each pattern group through the batched engine directly; warm-stream
plan-cache hits (the analyze phase is counter-asserted skipped); chunked
dispatch at a fixed batch_size (one compiled program per pattern); multi-
RHS and mixed-RHS-shape traffic; kernel-mode routing end-to-end at routing
scale (circuit→rowrow, banded/denseish→hybrid through the service); and a
disk-warm second service instance."""
import numpy as np
import pytest

from repro.core import CSR, HyluOptions
from repro.core.api import factor_batched, solve_batched, plan_fingerprint
from repro.core.plan_cache import PlanCache
from repro.serve.solver_service import (SolverService, SolveRequest,
                                        SolveResult)

from tests.helpers import SCENARIOS, scenario_system, routing_system

STREAM = ["circuit", "banded", "denseish", "singleton"]


def _mixed_stream(reps=3, n=36, seed=0):
    """Interleaved requests over ≥3 distinct patterns, with per-request
    value drift; returns (requests, per_pattern_indices).  ``seed`` drifts
    the values/RHS only — the patterns are fixed, so streams with
    different seeds hit the same plan-cache entries."""
    rng = np.random.default_rng(seed)
    pats = {name: scenario_system(name, n=n, seed=0)[0]
            for name in STREAM}
    reqs, per_pattern = [], {}
    for rep in range(reps):
        for name in STREAM:
            Ac = pats[name]
            vals = Ac.data * rng.uniform(0.9, 1.1, Ac.nnz)
            reqs.append(SolveRequest(
                a=CSR(Ac.n, Ac.indptr, Ac.indices, vals),
                b=rng.normal(size=Ac.n), tag=(name, rep)))
            per_pattern.setdefault(name, []).append(len(reqs) - 1)
    return reqs, per_pattern


def test_mixed_stream_residuals_and_bit_identity(tmp_path):
    """≥3 distinct patterns interleaved: per-request residual at target,
    and x bit-identical to the single-pattern batched engine fed the same
    group (asserted ≤1e-10, observed 0.0)."""
    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    reqs, per_pattern = _mixed_stream(reps=3)
    assert len(per_pattern) >= 3
    res = svc.solve_batch(reqs)
    assert len(res) == len(reqs)
    for r, req in zip(res, reqs):
        assert isinstance(r, SolveResult)
        assert r.tag == req.tag               # results in request order
        assert r.residual < 1e-10, r.tag
    assert svc.stats["patterns_seen"] == len(STREAM)
    # bit-identity against direct per-pattern dispatch (same group order,
    # same padding discipline as the service's batch_size=4 chunks)
    for name, idxs in per_pattern.items():
        a0 = reqs[idxs[0]].a
        an = svc.cache.get_or_analyze(a0, svc.opts)
        vb = np.stack([reqs[i].a.data for i in idxs] + [reqs[idxs[0]].a.data])
        bb = np.stack([reqs[i].b for i in idxs] + [np.zeros(a0.n)])
        x, _ = solve_batched(factor_batched(an, a0, vb), bb)
        for j, i in enumerate(idxs):
            assert np.abs(res[i].x - x[j]).max() <= 1e-10, (name, j)
            assert np.abs(res[i].x - x[j]).max() == 0.0, (name, j)


def test_results_match_scalar_solver(tmp_path):
    """Each request's x also matches the scalar analyze/factor/solve path
    to solver accuracy (different refinement trajectory ⇒ not bit-equal)."""
    from repro.core.api import analyze, factor, solve

    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    reqs, _ = _mixed_stream(reps=1)
    res = svc.solve_batch(reqs)
    for r, req in zip(res, reqs):
        x_ref, info = solve(factor(analyze(req.a), req.a), req.b)
        assert np.abs(r.x - x_ref).max() < 1e-8, r.tag


def test_warm_stream_skips_analyze(tmp_path):
    """Second traffic window over the same patterns: plan-cache memory
    hits, zero new analyze calls (the counter IS the phase-skip assert)."""
    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    reqs, _ = _mixed_stream(reps=1, seed=0)
    svc.solve_batch(reqs)
    n_analyze = svc.cache.stats["analyze_calls"]
    assert n_analyze == len(STREAM)
    reqs2, _ = _mixed_stream(reps=2, seed=99)     # new values, same patterns
    res2 = svc.solve_batch(reqs2)
    assert svc.cache.stats["analyze_calls"] == n_analyze
    assert svc.cache.stats["hits"] >= len(STREAM)
    for r in res2:
        assert r.residual < 1e-10


def test_disk_warm_second_service(tmp_path):
    """A fresh service over the same artifact store loads every plan from
    checkpoints/ (disk hits), skips analyze entirely, and returns
    bit-identical results."""
    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    reqs, _ = _mixed_stream(reps=1)
    res1 = svc.solve_batch(reqs)

    svc2 = SolverService(cache_dir=str(tmp_path), batch_size=4)
    res2 = svc2.solve_batch(reqs)
    assert svc2.cache.stats["analyze_calls"] == 0
    assert svc2.cache.stats["disk_hits"] == len(STREAM)
    for r1, r2 in zip(res1, res2):
        assert np.abs(r1.x - r2.x).max() == 0.0


def test_batch_size_chunking_and_padding(tmp_path):
    """5 same-pattern requests at batch_size=2 → 3 dispatches, 1 padded
    system, correct per-request results."""
    svc = SolverService(cache_dir=str(tmp_path), batch_size=2)
    rng = np.random.default_rng(5)
    Ac, _, _, _ = scenario_system("circuit", n=36, seed=5)
    reqs = [SolveRequest(a=CSR(Ac.n, Ac.indptr, Ac.indices,
                               Ac.data * rng.uniform(0.9, 1.1, Ac.nnz)),
                         b=rng.normal(size=Ac.n), tag=i) for i in range(5)]
    res = svc.solve_batch(reqs)
    assert svc.stats["dispatches"] == 3
    assert svc.stats["padded_systems"] == 1
    assert svc.stats["groups"] == 1
    for i, r in enumerate(res):
        assert r.tag == i and r.residual < 1e-10 and r.group_size == 5
    # every chunk reused ONE compiled batched program (padded to K=2)
    an = svc.cache.get_or_analyze(reqs[0].a, svc.opts)
    assert len(an.jit_cache) == 1


def test_multirhs_and_mixed_shapes(tmp_path):
    """(n,) and (n, m) requests of one pattern dispatch as separate
    rectangular groups; multi-RHS residuals are per-column."""
    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    rng = np.random.default_rng(11)
    Ac, _, _, _ = scenario_system("circuit", n=36, seed=11)
    reqs = [SolveRequest(a=Ac, b=rng.normal(size=Ac.n), tag="vec"),
            SolveRequest(a=Ac, b=rng.normal(size=(Ac.n, 3)), tag="multi")]
    res = svc.solve_batch(reqs)
    assert svc.stats["groups"] == 2
    assert res[0].x.shape == (Ac.n,) and np.ndim(res[0].residual) == 0
    assert res[1].x.shape == (Ac.n, 3)
    assert np.asarray(res[1].residual).shape == (3,)
    assert float(np.max(res[1].residual)) < 1e-10


def test_submit_flush_and_pairs(tmp_path):
    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    rng = np.random.default_rng(2)
    Ac, _, b, _ = scenario_system("circuit", n=36, seed=2)
    assert svc.submit(Ac, b, tag="q0") == 0
    assert svc.submit(Ac, rng.normal(size=Ac.n)) == 1
    res = svc.flush()
    assert len(res) == 2 and res[0].tag == "q0"
    assert svc._pending == []
    # bare (a, b) pairs are accepted by solve_batch
    res2 = svc.solve_batch([(Ac, b)])
    assert res2[0].residual < 1e-10


def test_bad_requests_get_typed_rejections(tmp_path):
    """Malformed requests never raise out of solve_batch — they come back
    as typed ``rejected`` results in place (taxonomy codes), so the rest
    of the window is dispatched normally."""
    from repro.serve.solver_service import (ERR_SHAPE_MISMATCH,
                                            ERR_BAD_MATRIX, STATUS_REJECTED,
                                            STATUS_SOLVED)

    svc = SolverService(cache_dir=str(tmp_path))
    Ac, _, b, _ = scenario_system("circuit", n=36, seed=0)
    res = svc.solve_batch([SolveRequest(a=Ac, b=np.zeros(Ac.n + 1)),
                           SolveRequest(a=np.eye(3), b=np.zeros(3)),
                           SolveRequest(a=Ac, b=b)])
    assert res[0].status == STATUS_REJECTED
    assert res[0].error.code == ERR_SHAPE_MISMATCH
    assert res[1].status == STATUS_REJECTED
    assert res[1].error.code == ERR_BAD_MATRIX
    assert res[2].status == STATUS_SOLVED and res[2].residual < 1e-10
    assert svc.stats["rejected"] == 2
    with pytest.raises(ValueError, match="batch_size"):
        SolverService(batch_size=0)


def test_submit_validates_eagerly_and_flush_never_loses_the_window(
        tmp_path):
    """The window can only ever hold admissible requests: a malformed
    submit raises a typed InvalidRequestError immediately (nothing is
    queued), and flush always clears the queue with one terminal result
    per queued request."""
    from repro.serve.solver_service import (InvalidRequestError,
                                            ERR_SHAPE_MISMATCH,
                                            ERR_NONFINITE_VALUES)

    svc = SolverService(cache_dir=str(tmp_path), batch_size=4)
    Ac, _, b, _ = scenario_system("circuit", n=36, seed=0)
    svc.submit(Ac, b, tag="good")
    with pytest.raises(InvalidRequestError) as ei:
        svc.submit(Ac, np.zeros(Ac.n + 1), tag="bad")
    assert ei.value.error.code == ERR_SHAPE_MISMATCH
    bad_vals = Ac.data.copy()
    bad_vals[0] = np.nan
    with pytest.raises(InvalidRequestError) as ei:
        svc.submit(CSR(Ac.n, Ac.indptr, Ac.indices, bad_vals), b)
    assert ei.value.error.code == ERR_NONFINITE_VALUES
    assert len(svc._pending) == 1              # only the good one queued
    res = svc.flush()
    assert len(res) == 1 and res[0].tag == "good"
    assert res[0].residual < 1e-10
    assert svc._pending == []


def test_shared_cache_across_services(tmp_path):
    """Two services sharing one PlanCache share analyses and engines."""
    cache = PlanCache(directory=str(tmp_path))
    s1 = SolverService(cache=cache, batch_size=2)
    s2 = SolverService(cache=cache, batch_size=2)
    Ac, _, b, _ = scenario_system("circuit", n=36, seed=0)
    s1.solve_batch([(Ac, b)])
    s2.solve_batch([(Ac, b)])
    assert cache.stats["analyze_calls"] == 1
    assert cache.stats["hits"] == 1


# --------------------------------------------------------------------------
# kernel-mode routing end-to-end (at routing scale): the scenario
# generators really land on their intended kernels through the service
# --------------------------------------------------------------------------
@pytest.mark.parametrize("name,expected", [("circuit", "rowrow"),
                                           ("banded", "hybrid"),
                                           ("denseish", "hybrid")])
def test_service_routes_kernel_modes_end_to_end(tmp_path, name, expected):
    Ac, b, expected2 = routing_system(name, seed=0)
    assert expected2 == expected == SCENARIOS[name][2]
    svc = SolverService(cache_dir=str(tmp_path), batch_size=2)
    res = svc.solve_batch([(Ac, b)])
    assert res[0].residual < 1e-10
    fp = plan_fingerprint(Ac, svc.opts)
    assert svc.pattern_modes[fp] == expected


def test_force_mode_wins_through_service(tmp_path):
    """force_mode overrides routing through the whole serving stack, and
    the forced-mode entry is a distinct fingerprint from the routed one."""
    Ac, _, b, _ = scenario_system("circuit", n=36, seed=0)
    routed = SolverService(cache_dir=str(tmp_path), batch_size=2)
    forced = SolverService(opts=HyluOptions(force_mode="supernodal"),
                           cache=routed.cache, batch_size=2)
    r0 = routed.solve_batch([(Ac, b)])[0]
    r1 = forced.solve_batch([(Ac, b)])[0]
    assert r0.fingerprint != r1.fingerprint
    assert routed.pattern_modes[r0.fingerprint] == "rowrow"
    assert forced.pattern_modes[r1.fingerprint] == "supernodal"
    assert routed.cache.stats["analyze_calls"] == 2
    assert r1.residual < 1e-10
    assert np.abs(r0.x - r1.x).max() < 1e-8   # same solution, different plan
