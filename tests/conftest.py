import os

# keep tests on 1 device; dryrun tests spawn subprocesses with their own flags
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
