import os

# keep tests on 1 device; dryrun tests spawn subprocesses with their own flags
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

# x64 on by default; the CI float32-only job sets JAX_ENABLE_X64=0 to prove
# the engine's x64 guard raises instead of silently degrading (see
# tests/test_x64_guard.py)
jax.config.update("jax_enable_x64",
                  os.environ.get("JAX_ENABLE_X64", "1").lower()
                  not in ("0", "false"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
